#!/usr/bin/env python3
"""Bit-exact python mirror of the serving simulator's decision math.

Mirrors, straight from the rust sources (plain python3, no dependencies):

* ``rust/src/util/rng.rs``       — SplitMix64-seeded xoshiro256**;
* ``rust/src/serve/trace.rs``    — Poisson / bursty-MMPP / diurnal traces;
* ``rust/src/serve/mod.rs``      — route-matrix construction and per-token
                                   weighted expert sampling;
* ``rust/src/serve/cache.rs``    — expert-weight cache residency (LRU and
                                   EWMA-prioritized retention);
* ``rust/src/serve/batcher.rs``  — continuous-batching admission, token
                                   accounting, and retirement;
* ``rust/src/metrics/mod.rs``    — nearest-rank percentiles.

Every floating-point step follows IEEE-754 double semantics, so the
sequences here equal the rust ones bit for bit; the golden vectors
asserted below are the same constants pinned in the rust unit tests.
Run ``python3 python/serve_mirror.py`` — it prints a short report and
exits nonzero on the first violated invariant.
"""

import math
import sys

MASK = (1 << 64) - 1

# ------------------------------------------------------------------ rng


class Rng:
    """xoshiro256** seeded via SplitMix64 (util/rng.rs)."""

    def __init__(self, seed):
        x = seed & MASK
        s = []
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & MASK
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK

    def next_u64(self):
        s = self.s
        result = (self._rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        return (self.next_u64() * n) >> 64  # Lemire multiply-shift

    def range(self, lo, hi):
        return lo + self.below(hi - lo)

    def weighted(self, weights):
        # rust sums left to right; fsum would compensate differently
        total = 0.0
        for w in weights:
            total += w
        assert total > 0.0
        x = self.f64() * total
        for i, w in enumerate(weights):
            x -= w
            if x <= 0.0:
                return i
        return len(weights) - 1


# ---------------------------------------------------------------- traces

BURST_HIGH_X = 4.0
BURST_LOW_DIV = 4.0
BURST_SWITCH_P = 0.08
DIURNAL_PERIOD_S = 120.0
DIURNAL_AMPL = 0.8


def exp_gap(rng, rate):
    return -math.log(max(rng.f64(), 1e-300)) / rate


def span_sample(rng, mean):
    lo = max(mean // 2, 1)
    hi = max(-(-3 * mean // 2), lo + 1)  # div_ceil(3·mean, 2)
    return rng.range(lo, hi)


def generate_trace(kind, rate_rps, n_requests, seed, prompt_mean=32, output_mean=16):
    """Mirror of serve/trace.rs::generate. Returns [(arrival, prompt, output)]."""
    assert rate_rps > 0.0
    rng = Rng(seed)
    t = 0.0
    burst_on = False
    out = []
    for _ in range(n_requests):
        if kind == "poisson":
            t += exp_gap(rng, rate_rps)
        elif kind == "bursty":
            rate = rate_rps * BURST_HIGH_X if burst_on else rate_rps / BURST_LOW_DIV
            t += exp_gap(rng, rate)
            if rng.f64() < BURST_SWITCH_P:
                burst_on = not burst_on
        elif kind == "diurnal":
            peak = rate_rps * (1.0 + DIURNAL_AMPL)
            while True:
                t += exp_gap(rng, peak)
                rate_t = rate_rps * (
                    1.0 + DIURNAL_AMPL * math.sin(2.0 * math.pi * t / DIURNAL_PERIOD_S)
                )
                if rng.f64() * peak < rate_t:
                    break
        else:
            raise ValueError(kind)
        prompt = span_sample(rng, prompt_mean)
        output = span_sample(rng, output_mean)
        out.append((t, prompt, output))
    return out


# ----------------------------------------------------------------- routing


def route_row(base_row, e_per_dev, zipf_s):
    """Mirror of serve/mod.rs::route_matrix for one device row."""
    row = [max(b, 0.0) * (1.0 + (e % e_per_dev)) ** (-zipf_s) for e, b in enumerate(base_row)]
    total = 0.0
    for w in row:
        total += w
    n = len(row)
    if total > 0.0:
        return [w / total for w in row]
    return [1.0 / n] * n


def sample_counts(rng, route, tokens, k):
    """Mirror of ServeSession::sample_counts: fixed (device, token, draw) order."""
    p, n = len(route), len(route[0])
    counts = [[0.0] * n for _ in range(p)]
    for dev in range(p):
        t = tokens[dev]
        if t == 0:
            continue
        row = route[dev]
        for _ in range(t):
            for _ in range(k):
                counts[dev][rng.weighted(row)] += 1.0
    return counts


# ------------------------------------------------------------------- cache


class ExpertCache:
    """Mirror of serve/cache.rs::ExpertCache (identity placement)."""

    def __init__(self, p, e_per_dev, cap, policy, alpha=0.25):
        assert 0.0 < alpha <= 1.0
        n = p * e_per_dev
        self.p, self.e_per_dev, self.cap = p, e_per_dev, cap
        self.policy, self.alpha = policy, alpha
        self.resident = [cap == 0] * n
        self.stamp = [0] * n
        self.ewma = [0.0] * n
        self.tick = 0
        self.hits = 0
        self.misses = 0

    def priority(self, e):
        recency = float(self.stamp[e]) - e / (self.p * self.e_per_dev)
        if self.policy == "lru":
            return recency
        return self.ewma[e] * 1e9 + recency  # ewma

    def access(self, col_loads, device_of):
        n = self.p * self.e_per_dev
        self.tick += 1
        hits = misses = 0
        fetch = []
        for e in range(n):
            load = col_loads[e]
            self.ewma[e] = (1.0 - self.alpha) * self.ewma[e] + self.alpha * load
            if load <= 0.0:
                continue
            if self.resident[e]:
                hits += 1
            else:
                misses += 1
                fetch.append((e // self.e_per_dev, device_of(e)))
            self.stamp[e] = self.tick
            self.resident[e] = True
        if self.cap > 0:
            self.settle(device_of)
        self.hits += hits
        self.misses += misses
        return hits, misses, fetch

    def settle(self, device_of):
        n = self.p * self.e_per_dev
        for dev in range(self.p):
            here = [e for e in range(n) if device_of(e) == dev and self.resident[e]]
            if len(here) <= self.cap:
                continue
            here.sort(key=self.priority, reverse=True)
            for e in here[self.cap :]:
                self.resident[e] = False


# ----------------------------------------------------------------- batcher


class ContinuousBatcher:
    """Mirror of serve/batcher.rs::ContinuousBatcher."""

    def __init__(self, trace, p, max_inflight_per_dev):
        assert p > 0 and max_inflight_per_dev > 0
        self.trace = trace
        self.next = 0
        self.inflight = []  # [id, arrival, prompt, output, emitted, dev, first]
        self.per_dev = [0] * p
        self.max = max_inflight_per_dev

    def _open_device(self):
        dev = min(range(len(self.per_dev)), key=lambda d: (self.per_dev[d], d))
        return dev if self.per_dev[dev] < self.max else None

    def admit(self, now):
        admitted = 0
        while self.next < len(self.trace) and self.trace[self.next][0] <= now:
            dev = self._open_device()
            if dev is None:
                break
            arrival, prompt, output = self.trace[self.next]
            self.inflight.append([self.next, arrival, prompt, max(output, 1), 0, dev, None])
            self.per_dev[dev] += 1
            self.next += 1
            admitted += 1
        return admitted

    def tokens_per_device(self):
        t = [0] * len(self.per_dev)
        for s in self.inflight:
            t[s[5]] += s[2] if s[4] == 0 else 1
        return t

    def advance(self, now_end):
        done, keep = [], []
        for s in self.inflight:
            if s[4] == 0:
                s[6] = now_end
            s[4] += 1
            if s[4] >= s[3]:
                self.per_dev[s[5]] -= 1
                done.append((s[0], s[1], s[6], now_end, s[2], s[3]))
            else:
                keep.append(s)
        self.inflight = keep
        done.sort(key=lambda r: r[0])
        return done

    def next_arrival(self):
        return self.trace[self.next][0] if self.next < len(self.trace) else None

    def done(self):
        return self.next >= len(self.trace) and not self.inflight


# --------------------------------------------------------------- metrics


def percentile(xs, q):
    """Nearest-rank percentile (metrics/mod.rs; quickselect there, sort here)."""
    if not xs:
        return None
    n = len(xs)
    q = min(max(q, 0.0), 100.0)
    rank = min(max(int(math.ceil(q / 100.0 * n)), 1), n)
    return sorted(xs)[rank - 1]


# ---------------------------------------------------------------- checks

FAILURES = []


def check(name, cond, detail=""):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {name}" + (f" — {detail}" if detail and not cond else ""))
    if not cond:
        FAILURES.append(name)


def main():
    print("serve_mirror: bit-exact decision-math mirror\n")

    # -- rng golden vector (pinned in rust/src/util/rng.rs tests) --------
    print("rng:")
    r = Rng(42)
    golden = [r.next_u64() for _ in range(4)]
    check(
        "xoshiro256** golden vector, seed 42",
        golden
        == [
            0x15780B2E0C2EC716,
            0x6104D9866D113A7E,
            0xAE17533239E499A1,
            0xECB8AD4703B360A1,
        ],
        f"got {[hex(g) for g in golden]}",
    )
    r = Rng(42)
    check("f64 golden, seed 42", r.f64() == 0.08386297105988216, f"got {Rng(42).f64()!r}")
    r = Rng(7)
    check("below(10) golden, seed 7", [r.below(10) for _ in range(4)] == [7, 2, 8, 9])
    a, b = Rng(5), Rng(5)
    check("determinism in seed", all(a.next_u64() == b.next_u64() for _ in range(256)))

    # -- traces ----------------------------------------------------------
    print("traces:")
    for kind in ("poisson", "bursty", "diurnal"):
        t1 = generate_trace(kind, 20.0, 64, 7)
        t2 = generate_trace(kind, 20.0, 64, 7)
        check(f"{kind} deterministic in seed", t1 == t2)
        check(
            f"{kind} sorted, lengths in band",
            all(x[0] <= y[0] for x, y in zip(t1, t1[1:]))
            and all(16 <= r[1] < 48 and 8 <= r[2] < 24 for r in t1),
        )
    first = generate_trace("poisson", 20.0, 1, 42)[0]
    check(
        "poisson golden first request, seed 42",
        first == (0.1239285554529295, 28, 18),
        f"got {first!r}",
    )

    def cv2(kind):
        arr = [r[0] for r in generate_trace(kind, 20.0, 512, 11)]
        gaps = [b - a for a, b in zip(arr, arr[1:])]
        mean = sum(gaps) / len(gaps)
        return sum((g - mean) ** 2 for g in gaps) / len(gaps) / (mean * mean)

    check(
        "bursty dispersion exceeds poisson",
        cv2("bursty") > cv2("poisson") * 1.5,
        f"bursty {cv2('bursty'):.2f} vs poisson {cv2('poisson'):.2f}",
    )

    # -- routing ---------------------------------------------------------
    print("routing:")
    base = [3.0, 1.0, 0.5, 0.5]  # one device's converged dispatch row
    row = route_row(base, 2, 1.0)
    check("route row normalised", abs(sum(row) - 1.0) < 1e-12)
    check("zipf tilt favours expert 0 of each block", row[0] > row[1] and row[2] > row[3])
    check("zero row falls back to uniform", route_row([0.0, 0.0], 2, 1.0) == [0.5, 0.5])
    rng = Rng(9)
    counts = sample_counts(rng, [row, row], [100, 0], 2)
    check(
        "sampling conserves k·tokens per device",
        sum(counts[0]) == 200.0 and sum(counts[1]) == 0.0,
    )
    check("hot expert drew the most tokens", counts[0][0] == max(counts[0]))

    # -- cache -----------------------------------------------------------
    print("cache:")
    p, e = 4, 6
    n = p * e
    ident = lambda x: x // e

    def replay(policy, cap, seed):
        rng = Rng(seed)
        cache = ExpertCache(p, e, cap, policy)
        touched = set()
        for _ in range(60):
            loads = [0.0] * n
            for _ in range(p * 3):
                x = rng.below(n * (n + 1) // 2)
                acc = 0
                for cand in range(n):
                    acc += n - cand
                    if x < acc:
                        loads[cand] += 1.0
                        touched.add(cand)
                        break
            cache.access(loads, ident)
        return cache.hits, cache.misses, len(touched)

    for policy in ("lru", "ewma"):
        prev = -1
        ok = True
        for cap in range(1, e + 1):
            hits, misses, _ = replay(policy, cap, 42)
            ok = ok and hits >= prev
            prev = hits
        check(f"{policy} hit count monotone in capacity", ok)
        _, misses, touched = replay(policy, e, 99)
        check(f"{policy} full capacity -> compulsory misses only", misses == touched)
        hits, misses, _ = replay(policy, 0, 5)
        check(f"{policy} cap=0 disables caching", misses == 0 and hits > 0)

    # EWMA keeps the hot expert through a one-iteration cold burst; LRU
    # evicts it (the retention difference the acceptance test banks on)
    def burst(policy):
        cache = ExpertCache(2, 2, 1, policy)
        hot = lambda: cache.access([8.0, 0.0, 0.0, 0.0], lambda x: x // 2)
        for _ in range(6):
            hot()
        cache.access([0.0, 1.0, 0.0, 0.0], lambda x: x // 2)  # cold burst
        hits, misses, _ = hot()
        return hits

    check("ewma retains the hot expert through a burst", burst("ewma") == 1)
    check("lru drops the hot expert on the same burst", burst("lru") == 0)

    # -- batcher ---------------------------------------------------------
    print("batcher:")
    b = ContinuousBatcher([(0.0, 10, 3)], 1, 8)
    b.admit(0.0)
    ok = b.tokens_per_device() == [10]
    b.advance(0.25)
    ok = ok and b.tokens_per_device() == [1]
    b.advance(0.5)
    done = b.advance(0.75)
    rec = done[0]
    ttft = rec[2] - rec[1]
    tpot = (rec[3] - rec[2]) / (rec[5] - 1)
    check("prefill/decode token bill", ok)
    check("ttft and tpot math", ttft == 0.25 and abs(tpot - 0.25) < 1e-12 and b.done())

    trace = generate_trace("bursty", 50.0, 48, 9)
    b = ContinuousBatcher(trace, 4, 8)
    now, admitted, finished, records = 0.0, 0, 0, []
    while not b.done():
        if not b.inflight and b.next_arrival() is not None:
            now = max(now, b.next_arrival())
        admitted += b.admit(now)
        now += 0.01
        got = b.advance(now)
        finished += len(got)
        records.extend(got)
    check("conservation: every request admitted and retired", admitted == finished == 48)
    check(
        "lifecycle ordering on every record",
        all(r[1] < r[2] <= r[3] for r in records),
    )

    # -- percentiles -----------------------------------------------------
    print("percentiles:")
    rng = Rng(0xC0FFEE)
    ok = True
    for _ in range(100):
        m = 1 + rng.below(97)
        xs = [rng.f64() * 1e3 - 500.0 for _ in range(m)]
        srt = sorted(xs)
        for q in (0.0, 25.0, 50.0, 90.0, 99.0, 100.0):
            rank = min(max(int(math.ceil(q / 100.0 * m)), 1), m)
            ok = ok and percentile(xs, q) == srt[rank - 1]
    check("nearest-rank percentile matches the sort oracle", ok)
    check("empty and clamped edges", percentile([], 50.0) is None and percentile([1.0, 2.0], 250.0) == 2.0)

    ttfts = [r[2] - r[1] for r in records]
    p50, p99 = percentile(ttfts, 50.0), percentile(ttfts, 99.0)
    check("p99 dominates p50 on the replayed trace", p50 <= p99)

    print()
    if FAILURES:
        print(f"serve_mirror: {len(FAILURES)} FAILED: {', '.join(FAILURES)}")
    else:
        print("serve_mirror: all invariants hold")


if __name__ == "__main__":
    main()
    sys.exit(1 if FAILURES else 0)
