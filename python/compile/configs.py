"""Model configurations shared by the AOT pipeline, tests, and the manifest.

Each named config becomes one artifact directory under ``artifacts/<name>/``
containing ``init.hlo.txt``, ``step.hlo.txt``, ``eval.hlo.txt`` and
``manifest.json``. The rust coordinator selects a config by name.

Scale note (DESIGN.md §2): the paper trains GPT-Medium (d=1024, 12 layers)
on 8–64 GPUs. This testbed is one CPU core, so the *trained* configs here
are scaled down (d=64–128, 2–4 layers) while keeping every structural knob
the paper varies: expert count, gate type (Switch top-1 / GShard top-2 /
FasterMoE-Hir), capacity policy (DeepSpeed local / FastMoE global), and
capacity factor. The paper-scale shapes appear in the rust cost model
(``comm``/``coordinator``), not in the trained artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

CAP_ROUND = 8  # expert-buffer capacity is rounded up to a multiple of this


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/structure of one AOT-compiled MoE transformer."""

    name: str
    p: int              # simulated devices (= expert-parallel world size)
    e_per_dev: int      # experts per device (paper: 1)
    layers: int         # transformer blocks
    d: int              # hidden size
    f: int              # expert/FFN intermediate size
    heads: int          # attention heads
    vocab: int          # byte-level vocab (256)
    batch: int          # sequences per device
    seq: int            # tokens per sequence
    k: int              # gate top-k (1 = Switch, 2 = GShard)
    cap_factor: float   # expert capacity factor
    gate: str           # "switch" | "gshard" | "hir"
    dispatch: str       # "local" (DeepSpeed-style) | "global" (FastMoE-style)
    moe_every: int = 2  # MoE FFN every n-th layer (others dense)

    @property
    def n_experts(self) -> int:
        return self.p * self.e_per_dev

    @property
    def tokens_per_dev(self) -> int:
        """S in the paper: tokens each device contributes per step."""
        return self.batch * self.seq

    @property
    def capacity(self) -> int:
        """Static per-expert buffer size C (global, across all senders)."""
        raw = self.cap_factor * self.k * self.tokens_per_dev * self.p / self.n_experts
        c = int(-(-raw // 1))  # ceil
        return ((c + CAP_ROUND - 1) // CAP_ROUND) * CAP_ROUND

    def moe_layer_ids(self):
        """Indices of blocks whose FFN is a MoE layer.

        Counted from the top so the last block is always MoE (the gate
        closest to the loss adapts fastest — matches common practice)."""
        return [
            l for l in range(self.layers)
            if (self.layers - 1 - l) % self.moe_every == 0
        ]


def _mk(name, **kw) -> ModelConfig:
    return ModelConfig(name=name, **kw)


CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # Fast config for unit/integration tests (python + rust).
        _mk("tiny4", p=4, e_per_dev=1, layers=2, d=32, f=64, heads=2,
            vocab=256, batch=2, seq=16, k=1, cap_factor=1.5,
            gate="switch", dispatch="global", moe_every=1),
        # Switch top-1 / FastMoE-style global capacity — fig3/6b/7 runs.
        _mk("small8_switch", p=8, e_per_dev=1, layers=4, d=128, f=256,
            heads=4, vocab=256, batch=2, seq=32, k=1, cap_factor=1.25,
            gate="switch", dispatch="global", moe_every=2),
        # GShard top-2 / DeepSpeed-style local capacity.
        _mk("small8_gshard", p=8, e_per_dev=1, layers=4, d=128, f=256,
            heads=4, vocab=256, batch=2, seq=32, k=2, cap_factor=2.0,
            gate="gshard", dispatch="local", moe_every=2),
        # FasterMoE Hir compulsory-ratio gate — fig5 comparison.
        _mk("small8_hir", p=8, e_per_dev=1, layers=4, d=128, f=256,
            heads=4, vocab=256, batch=2, seq=32, k=1, cap_factor=1.25,
            gate="hir", dispatch="global", moe_every=2),
        # Wider world for dispatch-distribution experiments (fig6b/fig7).
        _mk("wide16_switch", p=16, e_per_dev=1, layers=2, d=64, f=128,
            heads=2, vocab=256, batch=2, seq=32, k=1, cap_factor=1.25,
            gate="switch", dispatch="global", moe_every=1),
    ]
}

DEFAULT_ARTIFACTS = ["tiny4", "small8_switch", "small8_gshard", "small8_hir",
                     "wide16_switch"]
