"""Grouped expert FFN as a Pallas kernel — the MoE compute hot spot.

The paper's expert computation is a per-expert 2-layer MLP over the tokens
each expert received from the global exchange (GPU implementations run one
cuBLAS GEMM per expert or a grouped GEMM). TPU adaptation (DESIGN.md
§Hardware-Adaptation): we express the HBM↔VMEM staging with a BlockSpec
grid over ``(expert, capacity-tile)``; each grid step stages a
``[Cb, d]`` token tile plus that expert's ``[d, f]``/``[f, d]`` weight
panels through VMEM-shaped blocks and feeds MXU-shaped ``jnp.dot`` calls.
``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO; the *structure* (block
shapes, VMEM footprint, MXU tile occupancy) is what carries to real TPU and
is what the §Perf estimate in EXPERIMENTS.md is computed from.

``pallas_call`` has no automatic differentiation (even in interpret mode),
so the public entry point :func:`expert_ffn` is a ``jax.custom_vjp`` whose
forward *and* backward passes are Pallas kernels. The backward recomputes
the hidden activation instead of saving it (rematerialisation — halves the
residual footprint, the standard MoE trade since expert buffers dominate
memory).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred tokens-per-grid-step tile: 128 matches the MXU systolic
# dimension. The capacity axis is only guaranteed to be a multiple of
# configs.CAP_ROUND (8), so `_pick_tile` falls back to the largest tile
# that divides it — on real TPU one would instead round capacity up to a
# full 128 so every grid step fills the MXU (EXPERIMENTS.md §Perf).
CAP_TILE = 128


def _pick_tile(c: int) -> int:
    """Largest tile that divides the capacity axis, capped at CAP_TILE."""
    for t in (CAP_TILE, 64, 32, 16, 8, 4, 2, 1):
        if c % t == 0:
            return t
    return 1


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One (expert, token-tile) grid step of y = relu(x@w1+b1)@w2+b2."""
    x = x_ref[0]  # [Cb, d]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32) + b1_ref[0]
    a = jnp.maximum(h, 0.0)
    o_ref[0] = jnp.dot(a, w2_ref[0], preferred_element_type=jnp.float32) + b2_ref[0]


def _fwd(x, w1, b1, w2, b2):
    e, c, d = x.shape
    f = w1.shape[-1]
    cb = _pick_tile(c)
    grid = (e, c // cb)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, f), lambda i, j: (i, 0)),
            pl.BlockSpec((1, f, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _bwd_dx_kernel(x_ref, w1_ref, b1_ref, w2_ref, g_ref, gx_ref):
    """dL/dx for one (expert, token-tile): gx = (g@w2ᵀ · relu'(h)) @ w1ᵀ."""
    x = x_ref[0]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32) + b1_ref[0]
    ga = jnp.dot(g_ref[0], w2_ref[0].T, preferred_element_type=jnp.float32)
    gh = ga * (h > 0.0).astype(ga.dtype)
    gx_ref[0] = jnp.dot(gh, w1_ref[0].T, preferred_element_type=jnp.float32)


def _bwd_dw_kernel(x_ref, w1_ref, b1_ref, w2_ref, g_ref,
                   gw1_ref, gb1_ref, gw2_ref, gb2_ref):
    """Per-expert weight grads over the full capacity axis.

    The weight-gradient reduction runs over all C tokens of one expert, so
    the grid is 1-D over experts and the whole ``[C, d]`` buffer is staged
    per step (on a real TPU this block would be split with an accumulating
    out_spec; for the capacities used here it fits VMEM — see DESIGN.md
    §Perf).
    """
    x = x_ref[0]  # [C, d]
    g = g_ref[0]  # [C, d]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32) + b1_ref[0]
    a = jnp.maximum(h, 0.0)
    ga = jnp.dot(g, w2_ref[0].T, preferred_element_type=jnp.float32)
    gh = ga * (h > 0.0).astype(ga.dtype)
    gw1_ref[0] = jnp.dot(x.T, gh, preferred_element_type=jnp.float32)
    gb1_ref[0] = jnp.sum(gh, axis=0)
    gw2_ref[0] = jnp.dot(a.T, g, preferred_element_type=jnp.float32)
    gb2_ref[0] = jnp.sum(g, axis=0)


def _bwd(res, g):
    x, w1, b1, w2 = res
    e, c, d = x.shape
    f = w1.shape[-1]
    cb = _pick_tile(c)

    gx = pl.pallas_call(
        _bwd_dx_kernel,
        grid=(e, c // cb),
        in_specs=[
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, f), lambda i, j: (i, 0)),
            pl.BlockSpec((1, f, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, g)

    gw1, gb1, gw2, gb2 = pl.pallas_call(
        _bwd_dw_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, d, f), x.dtype),
            jax.ShapeDtypeStruct((e, f), x.dtype),
            jax.ShapeDtypeStruct((e, f, d), x.dtype),
            jax.ShapeDtypeStruct((e, d), x.dtype),
        ],
        interpret=True,
    )(x, w1, b1, w2, g)

    return gx, gw1, gb1, gw2, gb2


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


@jax.custom_vjp
def expert_ffn(x, w1, b1, w2, b2):
    """Grouped expert FFN: ``y[e] = relu(x[e] @ w1[e] + b1[e]) @ w2[e] + b2[e]``.

    Shapes: x [E, C, d], w1 [E, d, f], b1 [E, f], w2 [E, f, d], b2 [E, d]
    → y [E, C, d]. Matches :func:`kernels.ref.expert_ffn_ref` bit-for-bit in
    fp32 (same contraction order).
    """
    return _fwd(x, w1, b1, w2, b2)


def _vjp_fwd(x, w1, b1, w2, b2):
    return _fwd(x, w1, b1, w2, b2), (x, w1, b1, w2)


expert_ffn.defvjp(_vjp_fwd, _bwd)
