"""L1: Pallas kernels for the paper's compute hot spots.

``moe_ffn.expert_ffn`` — grouped per-expert 2-layer MLP (fwd + bwd kernels,
wrapped in custom_vjp). ``gating.gate_probs`` — gate projection + softmax.
``ref`` — pure-jnp oracles pinned by the pytest/hypothesis suite.
"""

from . import gating, moe_ffn, ref  # noqa: F401
from .gating import gate_probs  # noqa: F401
from .moe_ffn import expert_ffn  # noqa: F401
