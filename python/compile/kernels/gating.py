"""Gate projection + stable softmax as a Pallas kernel.

The gate is the second kernelised hot spot: every token computes ``softmax(x
@ wg)`` over all N experts each MoE layer. The kernel tiles the flat token
axis (the model flattens ``[P, S]`` into one axis before calling, so no vmap
over Pallas is needed) and keeps the full ``[d, N]`` gate panel resident —
N is at most a few hundred, so the panel is tiny next to the token tile.

As with :mod:`moe_ffn`, ``pallas_call`` has no AD, so the public entry is a
``jax.custom_vjp``. The backward is the closed-form softmax VJP
(``dlogits = p ⊙ (g − ⟨g, p⟩)``) expressed as a Pallas kernel for the
token-tiled part; the tiny ``gwg = xᵀ @ dlogits`` reduction stays in jnp
(it is one [d, N] GEMM over the whole batch — XLA fuses it fine, and a
Pallas accumulate over token tiles buys nothing at this size; see DESIGN.md
§Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOK_TILE = 128


def _pick_tile(s: int) -> int:
    for t in (TOK_TILE, 64, 32, 16, 8, 4, 2, 1):
        if s % t == 0:
            return t
    return 1


def _fwd_kernel(x_ref, wg_ref, p_ref):
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    p_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _fwd(x, wg):
    s, d = x.shape
    n = wg.shape[-1]
    sb = _pick_tile(s)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(s // sb,),
        in_specs=[
            pl.BlockSpec((sb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((sb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        interpret=True,
    )(x, wg)


def _bwd_kernel(p_ref, g_ref, wg_ref, dlogits_ref, gx_ref):
    p = p_ref[...]
    g = g_ref[...]
    dlogits = p * (g - jnp.sum(g * p, axis=-1, keepdims=True))
    dlogits_ref[...] = dlogits
    gx_ref[...] = jnp.dot(dlogits, wg_ref[...].T, preferred_element_type=jnp.float32)


def _vjp_fwd(x, wg):
    p = _fwd(x, wg)
    return p, (x, wg, p)


def _vjp_bwd(res, g):
    x, wg, p = res
    s, d = x.shape
    n = wg.shape[-1]
    sb = _pick_tile(s)
    dlogits, gx = pl.pallas_call(
        _bwd_kernel,
        grid=(s // sb,),
        in_specs=[
            pl.BlockSpec((sb, n), lambda i: (i, 0)),
            pl.BlockSpec((sb, n), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sb, n), lambda i: (i, 0)),
            pl.BlockSpec((sb, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, n), x.dtype),
            jax.ShapeDtypeStruct((s, d), x.dtype),
        ],
        interpret=True,
    )(p, g, wg)
    gwg = x.T @ dlogits
    return gx, gwg


@jax.custom_vjp
def gate_probs(x, wg):
    """``softmax(x @ wg)`` for a flat token batch.

    Shapes: x [S, d], wg [d, N] → probs [S, N]. Numerically identical to
    :func:`kernels.ref.gate_probs_ref` (same max-subtraction stabilisation).
    """
    return _fwd(x, wg)


gate_probs.defvjp(_vjp_fwd, _vjp_bwd)
