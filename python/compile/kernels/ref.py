"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. The pytest suite (and the
hypothesis sweeps) assert ``assert_allclose(kernel(...), ref(...))`` over a
grid of shapes and dtypes, which is the correctness contract for the AOT
artifacts: the lowered HLO contains the *kernel* path, and the oracle pins
its numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Grouped expert FFN (the MoE expert computation hot spot)
# ---------------------------------------------------------------------------


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Grouped 2-layer ReLU MLP applied per expert.

    Args:
      x:  [E, C, d]  capacity-padded token buffers, one per expert.
      w1: [E, d, f]  first-layer weights.
      b1: [E, f]     first-layer biases.
      w2: [E, f, d]  second-layer weights.
      b2: [E, d]     second-layer biases.

    Returns:
      y: [E, C, d]
    """
    h = jnp.einsum("ecd,edf->ecf", x, w1) + b1[:, None, :]
    a = jnp.maximum(h, 0.0)
    return jnp.einsum("ecf,efd->ecd", a, w2) + b2[:, None, :]


def expert_ffn_vjp_ref(x, w1, b1, w2, b2, g):
    """Reference VJP of :func:`expert_ffn_ref` (via jax.vjp)."""
    _, vjp = jax.vjp(expert_ffn_ref, x, w1, b1, w2, b2)
    return vjp(g)


# ---------------------------------------------------------------------------
# Gate probabilities (projection + stable softmax)
# ---------------------------------------------------------------------------


def gate_probs_ref(x, wg):
    """Gate probabilities for a flat batch of tokens.

    Args:
      x:  [S, d]  token activations.
      wg: [d, N]  gate projection.

    Returns:
      probs: [S, N] softmax(x @ wg), numerically stabilised.
    """
    logits = x @ wg
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def gate_probs_vjp_ref(x, wg, g):
    """Reference VJP of :func:`gate_probs_ref` (via jax.vjp)."""
    _, vjp = jax.vjp(gate_probs_ref, x, wg)
    return vjp(g)


# ---------------------------------------------------------------------------
# MoE dispatch/combine reference (used by model tests, not a kernel)
# ---------------------------------------------------------------------------


def topk_mask_ref(probs, k):
    """Top-k selection mask [S, N] (ones at each token's k largest probs)."""
    _, idx = jax.lax.top_k(probs, k)
    return jnp.sum(jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype), axis=-2)
