"""AOT lowering: JAX model → HLO **text** + manifest, per model config.

Interchange format is HLO text, not a serialized ``HloModuleProto``: jax
≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

For each config three programs are emitted under ``artifacts/<name>/``:

  * ``init.hlo.txt`` — ``seed:i32 → params`` (random init, fully in-graph so
    rust never needs numpy).
  * ``step.hlo.txt`` — one whole-cluster Adam training step (flat ABI, see
    :mod:`compile.model`).
  * ``eval.hlo.txt`` — validation loss + dispatch statistics.

plus ``manifest.json`` describing every input/output (name, shape, dtype)
in positional order — the ABI contract the rust ``runtime`` module loads.

Run as ``python -m compile.aot`` from the ``python/`` directory (this is
what ``make artifacts`` does). Python never runs again after this.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, DEFAULT_ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _desc(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_config(cfg, out_dir: str, verbose: bool = True) -> dict:
    """Lower init/step/eval for one config; write HLO text + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    specs = model.param_specs(cfg)
    n = len(specs)
    p_, b_, t_, n_e = cfg.p, cfg.batch, cfg.seq, cfg.n_experts

    param_descs = [_desc(name, shape) for name, shape in specs]
    data_descs = [
        _desc("t", ()), _desc("lr", ()),
        _desc("tokens", (p_, b_, t_), "i32"),
        _desc("targets", (p_, b_, t_), "i32"),
        _desc("penalty", (p_, n_e)),
        _desc("caps", (p_, n_e)),
        _desc("local_mask", (p_, n_e)),
        _desc("hir_remote_frac", ()),
    ]
    out_descs = (
        param_descs
        + [dict(d, name="m." + d["name"]) for d in param_descs]
        + [dict(d, name="v." + d["name"]) for d in param_descs]
        + [_desc("t", ()), _desc("loss", ()), _desc("ce", ()), _desc("aux", ()),
           _desc("counts", (p_, n_e)), _desc("dropped", ())]
    )

    def shape_structs(descs):
        return [
            _spec(tuple(d["shape"]), jnp.int32 if d["dtype"] == "i32" else jnp.float32)
            for d in descs
        ]

    timings = {}

    # init: seed -> params
    t0 = time.time()
    init_lowered = jax.jit(lambda s: tuple(model.init_params(cfg, s))).lower(
        _spec((), jnp.int32)
    )
    init_text = to_hlo_text(init_lowered)
    with open(os.path.join(out_dir, "init.hlo.txt"), "w") as fh:
        fh.write(init_text)
    timings["init"] = time.time() - t0

    # step: params, m, v, data -> params, m, v, metrics
    t0 = time.time()
    step_in = shape_structs(param_descs * 3 + data_descs)
    step_lowered = jax.jit(lambda *f: model.train_step(cfg, n, *f)).lower(*step_in)
    step_text = to_hlo_text(step_lowered)
    with open(os.path.join(out_dir, "step.hlo.txt"), "w") as fh:
        fh.write(step_text)
    timings["step"] = time.time() - t0

    # eval: params, tokens, targets, penalty, caps, local_mask, frac -> metrics
    t0 = time.time()
    eval_descs = param_descs + data_descs[2:]
    eval_lowered = jax.jit(lambda *f: model.eval_step(cfg, n, *f)).lower(
        *shape_structs(eval_descs)
    )
    eval_text = to_hlo_text(eval_lowered)
    with open(os.path.join(out_dir, "eval.hlo.txt"), "w") as fh:
        fh.write(eval_text)
    timings["eval"] = time.time() - t0

    manifest = {
        "name": cfg.name,
        "config": {
            **dataclasses.asdict(cfg),
            "n_experts": cfg.n_experts,
            "capacity": cfg.capacity,
            "tokens_per_dev": cfg.tokens_per_dev,
            "moe_layer_ids": cfg.moe_layer_ids(),
        },
        "n_param_tensors": n,
        "params": param_descs,
        "init": {
            "file": "init.hlo.txt",
            "inputs": [_desc("seed", (), "i32")],
            "outputs": param_descs,
        },
        "step": {
            "file": "step.hlo.txt",
            "inputs": param_descs
            + [dict(d, name="m." + d["name"]) for d in param_descs]
            + [dict(d, name="v." + d["name"]) for d in param_descs]
            + data_descs,
            "outputs": out_descs,
        },
        "eval": {
            "file": "eval.hlo.txt",
            "inputs": eval_descs,
            "outputs": [_desc("loss", ()), _desc("ce", ()), _desc("aux", ()),
                        _desc("counts", (p_, n_e)), _desc("dropped", ())],
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)

    if verbose:
        sizes = {k: os.path.getsize(os.path.join(out_dir, f"{k}.hlo.txt"))
                 for k in ("init", "step", "eval")}
        print(f"[aot] {cfg.name}: "
              + ", ".join(f"{k} {sizes[k]//1024}KiB in {timings[k]:.1f}s"
                          for k in sizes))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact root (default ../artifacts)")
    ap.add_argument("--configs", nargs="*", default=DEFAULT_ARTIFACTS,
                    help=f"config names (known: {sorted(CONFIGS)})")
    args = ap.parse_args()
    for name in args.configs:
        cfg = CONFIGS[name]
        lower_config(cfg, os.path.join(args.out_dir, name))


if __name__ == "__main__":
    main()
