"""L2: the MoE transformer (fwd/bwd) — the paper's model, in JAX.

One AOT-compiled program computes the **whole P-device training step**
(DESIGN.md §2): data batches carry a leading per-device axis, expert
parameters carry the global expert axis, and the all-to-all of expert
parallelism is a differentiable scatter/gather inside the program. The gate
statistics the paper's coordinator needs (raw dispatch counts ``c_ie``)
are program outputs; the topology-derived quantities it controls (penalty
matrix ``p_ie`` of Eq. 8, capacity matrix ``C_ie``, the intra-node mask and
the FasterMoE-Hir remote fraction) are program *inputs*. That split keeps
every topology decision in the rust coordinator and every FLOP in XLA.

Gate modes (paper §5):
  * ``switch`` — top-1 gating [Fedus et al.].
  * ``gshard`` — top-2 gating with normalised combine weights [Lepikhin et al.].
  * ``hir``    — FasterMoE's compulsory-ratio gate: at most ``frac·S`` tokens
                 per device may follow a remote preference; the rest are
                 forced to their best intra-node expert.

Dispatch (capacity) modes (paper §3.1):
  * ``local``  — DeepSpeed-MoE style: sender i may occupy at most
                 ``caps[i,e]`` slots of expert e; senders write disjoint
                 slices (offsets = exclusive cumsum of caps over senders).
  * ``global`` — FastMoE style: one global per-expert capacity, filled in
                 sender order after a size exchange (offsets = exclusive
                 cumsum of actual counts).

TA-MoE needs **no mode of its own**: on FastMoE it only replaces the aux
loss (penalty input), on DeepSpeed-MoE it additionally sets
``caps[i,e] ∝ ĉ_ie`` (paper §4.3) — both are runtime inputs here.

The auxiliary loss implemented is the unified
``l = Σ_ie penalty[i,e] · m_ie · (c_ie / S)`` (mean over devices and MoE
layers): with ``penalty = N`` it is exactly the load-balance loss of Eq. 1,
with ``penalty = N·P·p_ie`` it is the topology loss of Eq. 8.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import expert_ffn, gate_probs

# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat, ordered (name, shape) list — the ABI between python and rust."""
    d, f, n, t, v = cfg.d, cfg.f, cfg.n_experts, cfg.seq, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (v, d)),
        ("pos", (t, d)),
    ]
    moe_layers = set(cfg.moe_layer_ids())
    for l in range(cfg.layers):
        pre = f"l{l}."
        specs += [
            (pre + "ln1_s", (d,)), (pre + "ln1_b", (d,)),
            (pre + "wq", (d, d)), (pre + "wk", (d, d)),
            (pre + "wv", (d, d)), (pre + "wo", (d, d)),
            (pre + "ln2_s", (d,)), (pre + "ln2_b", (d,)),
        ]
        if l in moe_layers:
            specs += [
                (pre + "wg", (d, n)),
                (pre + "moe_w1", (n, d, f)), (pre + "moe_b1", (n, f)),
                (pre + "moe_w2", (n, f, d)), (pre + "moe_b2", (n, d)),
            ]
        else:
            specs += [
                (pre + "ffn_w1", (1, d, f)), (pre + "ffn_b1", (1, f)),
                (pre + "ffn_w2", (1, f, d)), (pre + "ffn_b2", (1, d)),
            ]
    specs += [("lnf_s", (d,)), ("lnf_b", (d,))]
    return specs


def init_params(cfg: ModelConfig, seed) -> List[jax.Array]:
    """Initialise the flat parameter list from an int32 seed scalar.

    Scaled-normal init for matmuls (1/sqrt(fan_in)), ones/zeros for
    layernorms and biases. Deterministic in ``seed``.
    """
    base = jax.random.PRNGKey(seed)
    out = []
    for i, (name, shape) in enumerate(param_specs(cfg)):
        key = jax.random.fold_in(base, i)
        leaf = name.split(".")[-1]
        if leaf.endswith("_s"):  # layernorm scales
            out.append(jnp.ones(shape, jnp.float32))
        elif leaf.endswith("_b") and len(shape) <= 2 and "w" not in leaf:
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            out.append(
                jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return out


def _as_dict(cfg: ModelConfig, flat: Sequence[jax.Array]):
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Transformer pieces
# ---------------------------------------------------------------------------


def _layernorm(x, s, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * s + b


def _attention(x, wq, wk, wv, wo, heads):
    """Causal multi-head self-attention. x: [B, T, d]."""
    b, t, d = x.shape
    hd = d // heads
    q = (x @ wq).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e30)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    return o @ wo


# ---------------------------------------------------------------------------
# Gating
# ---------------------------------------------------------------------------


def _topk(probs, k):
    """Iterative-argmax top-k.

    `lax.top_k` lowers to the `topk(..., largest=true)` HLO op, which the
    xla_extension 0.5.1 text parser predates; iterative argmax lowers to
    plain variadic reduces that round-trip fine. k is 1 or 2 here, so the
    unrolled loop costs nothing.
    """
    p = probs
    vals, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(p, axis=-1)
        oh = jax.nn.one_hot(idx, p.shape[-1], dtype=p.dtype)
        vals.append(jnp.sum(p * oh, axis=-1))
        idxs.append(idx)
        p = p - oh * 2.0  # mask the taken entry (probs ≤ 1 < 2)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1).astype(jnp.int32)


def _select_experts(cfg: ModelConfig, probs, local_mask, hir_remote_frac):
    """Choose k experts + combine weights per token.

    Args:
      probs: [P, S, N] gate probabilities.
      local_mask: [P, N] 1.0 where expert e lives on device i's node.
      hir_remote_frac: scalar — max fraction of tokens a device may send to
        a remote-node expert (only used by the ``hir`` gate).

    Returns:
      idx: [P, S, k] int32 expert choices, weights: [P, S, k] f32 combine
      weights (selection is stop-gradient; weights carry the gate gradient).
    """
    p_, s_, n_ = probs.shape
    if cfg.gate == "switch":
        vals, idx = _topk(probs, 1)
        return idx, vals
    if cfg.gate == "gshard":
        vals, idx = _topk(probs, 2)
        w = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
        return idx, w
    if cfg.gate == "hir":
        # FasterMoE Hir: cap the number of remote-preferring tokens per
        # device at floor(frac * S); the rest are forced to the best local
        # expert. Token ranking is by remote preference strength.
        #
        # NOTE: written without batched take_along_axis — the HLO-text
        # converter (xla_extension 0.5.1 era) rejects gathers with
        # operand_batching_dims, so selections go through one-hot sums and
        # the rank-among-remote test is an O(S²) pairwise comparison
        # (S ≤ a few hundred here, so this is cheap and fully fusible).
        neg = jnp.float32(-1e30)
        local_p = jnp.where(local_mask[:, None, :] > 0, probs, neg)
        remote_p = jnp.where(local_mask[:, None, :] > 0, neg, probs)
        best_local = jnp.argmax(local_p, axis=-1)            # [P, S]
        best_any = jnp.argmax(probs, axis=-1)                # [P, S]
        remote_score = jnp.max(remote_p, axis=-1)            # [P, S]
        best_any_1h = jax.nn.one_hot(best_any, n_, dtype=jnp.float32)
        prefers_remote = (
            jnp.sum(best_any_1h * local_mask[:, None, :], axis=-1) < 0.5
        )                                                    # [P, S]
        budget = jnp.floor(hir_remote_frac * s_).astype(jnp.int32)
        # rank among remote-preferring tokens = #(strictly stronger) +
        # #(equal with smaller token id) — a stable descending rank.
        score_m = jnp.where(prefers_remote, remote_score, neg)   # [P, S]
        stronger = score_m[:, None, :] > score_m[:, :, None]     # [P, S, S]
        tie = (score_m[:, None, :] == score_m[:, :, None]) & (
            jnp.arange(s_)[None, None, :] < jnp.arange(s_)[None, :, None]
        )
        rank = jnp.sum((stronger | tie).astype(jnp.int32), axis=-1)  # [P, S]
        keep_remote = prefers_remote & (rank < budget)
        chosen = jnp.where(keep_remote, best_any, best_local)  # [P, S]
        chosen_1h = jax.nn.one_hot(chosen, n_, dtype=jnp.float32)
        w = jnp.sum(chosen_1h * probs, axis=-1, keepdims=True)
        return chosen[..., None], w
    raise ValueError(f"unknown gate {cfg.gate!r}")


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------


def _moe_layer(cfg: ModelConfig, x, wg, w1, b1, w2, b2,
               penalty, caps, local_mask, hir_remote_frac):
    """One expert-parallel MoE FFN over all devices.

    Args:
      x: [P, S, d] post-LN activations (S = tokens per device).
      penalty/caps/local_mask: [P, N] runtime inputs (see module docstring).

    Returns:
      y: [P, S, d], aux: scalar topology/load loss, counts: [P, N] raw
      (pre-capacity) dispatch counts, dropped: scalar dropped-token fraction.
    """
    p_, s_, d_ = x.shape
    n_ = cfg.n_experts
    c_ = cfg.capacity
    k_ = 1 if cfg.gate in ("switch", "hir") else 2

    probs = gate_probs(x.reshape(p_ * s_, d_), wg).reshape(p_, s_, n_)
    idx, weights = _select_experts(cfg, probs, local_mask, hir_remote_frac)

    # --- Eq. 1 / Eq. 8 statistics ------------------------------------------
    sel = jax.nn.one_hot(idx, n_, dtype=jnp.float32)          # [P, S, k, N]
    counts = jnp.sum(sel, axis=(1, 2))                        # [P, N] raw c_ie
    m = jnp.mean(probs, axis=1)                               # [P, N] mean prob
    frac = counts / float(s_)
    aux = jnp.mean(jnp.sum(penalty * m * frac, axis=-1))      # mean over P

    # --- slot ordering: all 1st choices (by token) then all 2nd choices ----
    sel_slots = sel.transpose(0, 2, 1, 3).reshape(p_, k_ * s_, n_)
    idx_slots = idx.transpose(0, 2, 1).reshape(p_, k_ * s_)
    w_slots = weights.transpose(0, 2, 1).reshape(p_, k_ * s_)

    rank = jnp.cumsum(sel_slots, axis=1) - sel_slots          # [P, kS, N]
    rank = jnp.sum(rank * sel_slots, axis=-1)                 # [P, kS] rank within (i,e)

    caps_i = jnp.floor(caps)                                  # [P, N]
    if cfg.dispatch == "local":
        # DeepSpeed-style: disjoint sender slices of size caps[i,e].
        offsets = jnp.cumsum(caps_i, axis=0) - caps_i         # excl. cumsum over P
        cap_of_slot = jnp.sum(caps_i[:, None, :] * sel_slots, axis=-1)
        keep = rank < cap_of_slot
    else:
        # FastMoE-style: global per-expert capacity, filled in sender order
        # (models the size-exchange all-to-all).
        gcap = jnp.minimum(jnp.sum(caps_i, axis=0), float(c_))  # [N]
        cnt = jnp.sum(sel_slots, axis=1)                         # [P, N]
        offsets = jnp.cumsum(cnt, axis=0) - cnt
        gcap_of_slot = jnp.sum(gcap[None, None, :] * sel_slots, axis=-1)
        off_plus_rank = rank + jnp.sum(offsets[:, None, :] * sel_slots, axis=-1)
        keep = off_plus_rank < gcap_of_slot

    off_of_slot = jnp.sum(offsets[:, None, :] * sel_slots, axis=-1)
    gpos = rank + off_of_slot                                  # [P, kS]
    keep = keep & (gpos < float(c_))
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / float(p_ * k_ * s_)

    sentinel = n_ * c_
    dest = jnp.where(keep, idx_slots * c_ + gpos.astype(jnp.int32), sentinel)
    dest = dest.reshape(p_ * k_ * s_).astype(jnp.int32)

    # --- dispatch: differentiable scatter into expert buffers --------------
    x_slots = jnp.broadcast_to(
        x[:, None, :, :], (p_, k_, s_, d_)
    ).reshape(p_ * k_ * s_, d_)
    buf = jnp.zeros((n_ * c_ + 1, d_), x.dtype).at[dest].add(x_slots)
    expert_in = buf[: n_ * c_].reshape(n_, c_, d_)

    # --- expert compute: the Pallas hot spot -------------------------------
    expert_out = expert_ffn(expert_in, w1, b1, w2, b2)

    # --- combine: gather back + weighted sum over k slots ------------------
    out_ext = jnp.concatenate(
        [expert_out.reshape(n_ * c_, d_), jnp.zeros((1, d_), x.dtype)], axis=0
    )
    y_slots = out_ext[dest] * w_slots.reshape(p_ * k_ * s_)[:, None]
    y = jnp.sum(y_slots.reshape(p_, k_, s_, d_), axis=1)

    return y, aux, counts, dropped


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, flat_params, tokens, targets,
            penalty, caps, local_mask, hir_remote_frac):
    """Whole-cluster forward: CE + aux loss + dispatch statistics.

    tokens/targets: int32 [P, B, T]. Returns (loss, (ce, aux, counts,
    dropped)) with counts the mean raw c_ie over MoE layers, [P, N] f32.
    """
    ps = _as_dict(cfg, flat_params)
    p_, b_, t_ = tokens.shape
    d_ = cfg.d
    s_ = b_ * t_

    x = ps["embed"][tokens.reshape(-1)].reshape(p_ * b_, t_, d_)
    x = x + ps["pos"][None, :, :]

    aux_total = jnp.float32(0.0)
    counts_total = jnp.zeros((p_, cfg.n_experts), jnp.float32)
    dropped_total = jnp.float32(0.0)
    moe_layers = set(cfg.moe_layer_ids())

    for l in range(cfg.layers):
        pre = f"l{l}."
        h = _layernorm(x, ps[pre + "ln1_s"], ps[pre + "ln1_b"])
        x = x + _attention(h, ps[pre + "wq"], ps[pre + "wk"],
                           ps[pre + "wv"], ps[pre + "wo"], cfg.heads)
        h = _layernorm(x, ps[pre + "ln2_s"], ps[pre + "ln2_b"])
        if l in moe_layers:
            h_dev = h.reshape(p_, s_, d_)
            y, aux, counts, dropped = _moe_layer(
                cfg, h_dev, ps[pre + "wg"],
                ps[pre + "moe_w1"], ps[pre + "moe_b1"],
                ps[pre + "moe_w2"], ps[pre + "moe_b2"],
                penalty, caps, local_mask, hir_remote_frac,
            )
            x = x + y.reshape(p_ * b_, t_, d_)
            aux_total = aux_total + aux
            counts_total = counts_total + counts
            dropped_total = dropped_total + dropped
        else:
            # Dense FFN = the same Pallas kernel with a single expert group.
            y = expert_ffn(
                h.reshape(1, p_ * s_, d_),
                ps[pre + "ffn_w1"], ps[pre + "ffn_b1"],
                ps[pre + "ffn_w2"], ps[pre + "ffn_b2"],
            )
            x = x + y.reshape(p_ * b_, t_, d_)

    x = _layernorm(x, ps["lnf_s"], ps["lnf_b"])
    logits = x @ ps["embed"].T                                # tied head
    logits = logits - jax.lax.stop_gradient(
        jnp.max(logits, axis=-1, keepdims=True)
    )
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    tgt = targets.reshape(p_ * b_, t_)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - picked)

    n_moe = max(len(moe_layers), 1)
    aux_mean = aux_total / n_moe
    counts_mean = counts_total / n_moe
    dropped_mean = dropped_total / n_moe

    # Keep every runtime input alive in the lowered program: the HLO-text
    # converter drops unused parameters (e.g. local_mask under the switch
    # gate), which would silently shift the positional ABI the rust side
    # indexes by. 0·x is not foldable for floats pre-compile, so these
    # survive to HLO text and cost nothing after XLA's own optimiser runs.
    keepalive = 0.0 * (jnp.sum(local_mask) + hir_remote_frac)

    loss = ce + aux_mean + keepalive
    return loss, (ce, aux_mean, counts_mean, dropped_mean)


# ---------------------------------------------------------------------------
# Train / eval steps (flat ABI for AOT)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def train_step(cfg: ModelConfig, n_params: int, *flat):
    """Flat-ABI Adam train step.

    Input order:  params×n, m×n, v×n, t, lr, tokens, targets, penalty, caps,
                  local_mask, hir_remote_frac.
    Output order: params×n, m×n, v×n, t+1, loss, ce, aux, counts, dropped.
    """
    params = list(flat[:n_params])
    m = list(flat[n_params: 2 * n_params])
    v = list(flat[2 * n_params: 3 * n_params])
    (t, lr, tokens, targets, penalty, caps, local_mask, hir_frac) = flat[3 * n_params:]

    def loss_fn(ps):
        return forward(cfg, ps, tokens, targets, penalty, caps,
                       local_mask, hir_frac)

    (loss, (ce, aux, counts, dropped)), grads = jax.value_and_grad(
        loss_fn, has_aux=True
    )(params)

    t1 = t + 1.0
    bc1 = 1.0 - ADAM_B1 ** t1
    bc2 = 1.0 - ADAM_B2 ** t1
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(params, m, v, grads):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * gi
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * jnp.square(gi)
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(pi - lr * upd)
        new_m.append(mi)
        new_v.append(vi)

    return tuple(new_p + new_m + new_v + [t1, loss, ce, aux, counts, dropped])


def eval_step(cfg: ModelConfig, n_params: int, *flat):
    """Flat-ABI eval: params×n, tokens, targets, penalty, caps, local_mask,
    hir_remote_frac → (loss, ce, aux, counts, dropped)."""
    params = list(flat[:n_params])
    tokens, targets, penalty, caps, local_mask, hir_frac = flat[n_params:]
    loss, (ce, aux, counts, dropped) = forward(
        cfg, params, tokens, targets, penalty, caps, local_mask, hir_frac
    )
    return loss, ce, aux, counts, dropped
