"""Build-time compile package: L1 Pallas kernels, L2 JAX model, AOT lowering.

Nothing in here runs at serving/training time — ``make artifacts`` invokes
``compile.aot`` once, and the rust coordinator consumes the emitted HLO text
+ manifest from ``artifacts/``.
"""
