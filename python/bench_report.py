#!/usr/bin/env python3
"""Fold bench output into the perf-trajectory baseline (BENCH_10.json).

Every bench is a ``harness = false`` main that appends one line to
``target/bench-results.jsonl`` (``util::bench::record_jsonl``)::

    {"bench": "<name>", "data": {<row>: <number> | {<field>: <number>}}}

This script folds those lines into a schema-stable report so CI can
archive one artifact per run and a future session can diff two of them
line by line:

* one entry per bench, keyed by bench name, sorted;
* each entry carries its headline rows with keys sorted and scalar rows
  normalised to ``{"value": x}`` so every row is an object;
* re-runs of the same bench in one jsonl (appends accumulate) keep the
  *last* record — the file is an append log, the report is a snapshot;
* top-level counts (``bench_count``, ``row_count``) give a one-glance
  coverage headline, and ``schema`` pins the layout for future diffs.

Usage::

    python3 python/bench_report.py                # target/bench-results.jsonl -> BENCH_10.json
    python3 python/bench_report.py --input X --output Y
    python3 python/bench_report.py --selftest

The script never runs benches; an empty or missing input yields a valid
empty report (CI uploads it either way, so the artifact always exists).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

SCHEMA = "ta-moe-bench-report/v1"

DEFAULT_INPUT = "target/bench-results.jsonl"
DEFAULT_OUTPUT = "BENCH_10.json"


def parse_lines(lines: List[str]) -> List[Tuple[str, Dict[str, object]]]:
    """Parse jsonl lines into (bench, data) pairs, skipping blanks.

    A malformed line is an error, not a skip: the jsonl is machine
    -written, so damage means a broken bench and should fail CI loudly.
    """
    out: List[Tuple[str, Dict[str, object]]] = []
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"line {i}: not JSON ({e})") from e
        if not isinstance(rec, dict) or "bench" not in rec or "data" not in rec:
            raise ValueError(f"line {i}: expected {{'bench': ..., 'data': ...}}")
        if not isinstance(rec["data"], dict):
            raise ValueError(f"line {i}: data must be an object")
        out.append((str(rec["bench"]), rec["data"]))
    return out


def normalise_row(value: object) -> Dict[str, object]:
    """Every row becomes an object: scalars wrap as {'value': x}."""
    if isinstance(value, dict):
        return {str(k): value[k] for k in sorted(value)}
    return {"value": value}


def fold(records: List[Tuple[str, Dict[str, object]]]) -> Dict[str, object]:
    """Fold parsed records into the schema-stable report dict."""
    latest: Dict[str, Dict[str, object]] = {}
    for bench, data in records:
        latest[bench] = data  # append log: last record wins
    benches: Dict[str, object] = {}
    row_count = 0
    for bench in sorted(latest):
        rows = {str(k): normalise_row(latest[bench][k]) for k in sorted(latest[bench])}
        row_count += len(rows)
        benches[bench] = {"rows": rows}
    return {
        "schema": SCHEMA,
        "bench_count": len(benches),
        "row_count": row_count,
        "benches": benches,
    }


def render(report: Dict[str, object]) -> str:
    """Canonical bytes: sorted keys, 2-space indent, trailing newline —
    so identical results produce identical artifacts."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"


# ----------------------------------------------------------- self-check


def selftest() -> int:
    lines = [
        '{"bench":"solver_hotpath","data":{"step_cost direct":{"mean_s":1e-4,"p50_s":9e-5}}}',
        '{"bench":"chaos_sweep","data":{"fastmoe/link":{"adaptive_s":1.5,"static_s":2.0}}}',
        "",
        '{"bench":"solver_hotpath","data":{"step_cost direct":{"mean_s":2e-4,"p50_s":1.8e-4}}}',
        '{"bench":"overlap_sweep","data":{"speedup":1.42}}',
    ]
    rep = fold(parse_lines(lines))
    assert rep["schema"] == SCHEMA
    assert rep["bench_count"] == 3
    assert rep["row_count"] == 3
    benches = rep["benches"]
    assert list(benches) == ["chaos_sweep", "overlap_sweep", "solver_hotpath"]
    # last record of a re-run bench wins
    hot = benches["solver_hotpath"]["rows"]["step_cost direct"]
    assert hot["mean_s"] == 2e-4, hot
    # scalar rows normalise to {'value': x}
    assert benches["overlap_sweep"]["rows"]["speedup"] == {"value": 1.42}
    # rendering is canonical: render(fold(x)) is a fixpoint under re-parse
    assert render(json.loads(render(rep))) == render(rep)
    # empty input is a valid empty report
    empty = fold(parse_lines([]))
    assert empty["bench_count"] == 0 and empty["benches"] == {}
    # malformed lines fail loudly
    for bad in ["not json", '{"bench":"x"}', '{"bench":"x","data":3}']:
        try:
            parse_lines([bad])
        except ValueError:
            pass
        else:
            raise AssertionError(f"{bad!r} must be rejected")
    print("bench_report: all self-checks passed")
    return 0


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--input", default=DEFAULT_INPUT, help="bench-results jsonl path")
    ap.add_argument("--output", default=DEFAULT_OUTPUT, help="report json path")
    ap.add_argument("--selftest", action="store_true", help="run self-checks and exit")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest()
    try:
        with open(args.input, encoding="utf-8") as f:
            lines = f.readlines()
    except FileNotFoundError:
        lines = []
        print(f"bench_report: {args.input} missing, writing empty report", file=sys.stderr)
    report = fold(parse_lines(lines))
    with open(args.output, "w", encoding="utf-8") as f:
        f.write(render(report))
    print(
        f"bench_report: {report['bench_count']} benches, "
        f"{report['row_count']} rows -> {args.output}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
