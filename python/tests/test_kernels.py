"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps are the contract for the AOT artifacts — they cover
the shape/dtype space the model can feed the kernels (including the
non-tile-aligned capacities produced by odd capacity factors).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_ffn, gate_probs, ref
from compile.kernels.moe_ffn import _pick_tile

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def _ffn_inputs(seed, e, c, d, f):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return (
        _rand(ks[0], (e, c, d)),
        _rand(ks[1], (e, d, f), 0.2),
        _rand(ks[2], (e, f), 0.1),
        _rand(ks[3], (e, f, d), 0.2),
        _rand(ks[4], (e, d), 0.1),
    )


class TestExpertFfnForward:
    @pytest.mark.parametrize("e,c,d,f", [
        (1, 4, 8, 16),     # degenerate single expert (dense-FFN reuse path)
        (2, 8, 16, 32),
        (4, 48, 32, 64),   # capacity not a power of two (tile fallback 16)
        (8, 128, 16, 32),  # full CAP_TILE
        (3, 7, 5, 9),      # fully unaligned shapes
    ])
    def test_matches_ref(self, e, c, d, f):
        args = _ffn_inputs(0, e, c, d, f)
        np.testing.assert_allclose(
            expert_ffn(*args), ref.expert_ffn_ref(*args), rtol=1e-5, atol=1e-5
        )

    def test_zero_input_rows_stay_zero_biasless(self):
        # Capacity padding relies on relu(0 @ w1 + 0) @ w2 + 0 == 0 when
        # biases are zero; with nonzero biases padded rows produce the bias
        # response, which the combine discards via the sentinel row.
        e, c, d, f = 2, 8, 4, 8
        x, w1, _, w2, _ = _ffn_inputs(1, e, c, d, f)
        zb1, zb2 = jnp.zeros((e, f)), jnp.zeros((e, d))
        y = expert_ffn(x.at[:, 2:].set(0.0), w1, zb1, w2, zb2)
        np.testing.assert_allclose(y[:, 2:], 0.0, atol=1e-7)

    def test_experts_independent(self):
        # Perturbing expert 0's buffer must not change expert 1's output.
        args = _ffn_inputs(2, 2, 8, 4, 8)
        y0 = expert_ffn(*args)
        x2 = args[0].at[0].add(1.0)
        y1 = expert_ffn(x2, *args[1:])
        np.testing.assert_allclose(y0[1], y1[1], atol=0)
        assert not np.allclose(y0[0], y1[0])


class TestExpertFfnBackward:
    @pytest.mark.parametrize("e,c,d,f", [(2, 8, 16, 32), (3, 48, 8, 16), (1, 5, 4, 6)])
    def test_grads_match_ref(self, e, c, d, f):
        args = _ffn_inputs(3, e, c, d, f)
        g = _rand(jax.random.PRNGKey(99), (e, c, d))
        got = jax.grad(lambda *a: jnp.sum(expert_ffn(*a) * g), argnums=(0, 1, 2, 3, 4))(*args)
        want = ref.expert_ffn_vjp_ref(*args, g)
        for gi, wi in zip(got, want):
            np.testing.assert_allclose(gi, wi, rtol=1e-4, atol=1e-5)

    def test_grad_through_jit(self):
        args = _ffn_inputs(4, 2, 16, 8, 16)
        f_ = jax.jit(jax.grad(lambda *a: jnp.sum(expert_ffn(*a) ** 2), argnums=0))
        r_ = jax.grad(lambda *a: jnp.sum(ref.expert_ffn_ref(*a) ** 2), argnums=0)
        np.testing.assert_allclose(f_(*args), r_(*args), rtol=1e-4, atol=1e-5)


class TestGateProbs:
    @pytest.mark.parametrize("s,d,n", [(4, 8, 2), (128, 16, 8), (100, 32, 64), (1, 4, 3)])
    def test_matches_ref(self, s, d, n):
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        x, wg = _rand(ks[0], (s, d)), _rand(ks[1], (d, n), 0.5)
        np.testing.assert_allclose(
            gate_probs(x, wg), ref.gate_probs_ref(x, wg), rtol=1e-5, atol=1e-6
        )

    def test_rows_sum_to_one(self):
        ks = jax.random.split(jax.random.PRNGKey(6), 2)
        p = gate_probs(_rand(ks[0], (64, 16)), _rand(ks[1], (16, 8)))
        np.testing.assert_allclose(jnp.sum(p, -1), 1.0, rtol=1e-6)
        assert (np.array(p) >= 0).all()

    def test_large_logits_stable(self):
        # Stability under huge logits (the max-subtraction path).
        x = jnp.full((8, 4), 50.0)
        wg = jnp.eye(4) * 10.0
        p = gate_probs(x, wg)
        assert np.isfinite(np.array(p)).all()

    def test_grads_match_ref(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x, wg = _rand(ks[0], (32, 8)), _rand(ks[1], (8, 4), 0.5)
        g = _rand(ks[2], (32, 4))
        got = jax.grad(lambda a, b: jnp.sum(gate_probs(a, b) * g), argnums=(0, 1))(x, wg)
        want = ref.gate_probs_vjp_ref(x, wg, g)
        for gi, wi in zip(got, want):
            np.testing.assert_allclose(gi, wi, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: the kernel/ref contract over the reachable shape space
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 6),
    c=st.integers(1, 96),
    d=st.integers(1, 48),
    f=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_ffn_forward(e, c, d, f, seed):
    args = _ffn_inputs(seed, e, c, d, f)
    np.testing.assert_allclose(
        expert_ffn(*args), ref.expert_ffn_ref(*args), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(
    e=st.integers(1, 4),
    c=st.integers(1, 32),
    d=st.integers(1, 16),
    f=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_ffn_backward(e, c, d, f, seed):
    args = _ffn_inputs(seed, e, c, d, f)
    g = _rand(jax.random.PRNGKey(seed ^ 0x5EED), (e, c, d))
    got = jax.grad(lambda *a: jnp.sum(expert_ffn(*a) * g), argnums=(0, 1, 2, 3, 4))(*args)
    want = ref.expert_ffn_vjp_ref(*args, g)
    for gi, wi in zip(got, want):
        np.testing.assert_allclose(gi, wi, rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    s=st.integers(1, 200),
    d=st.integers(1, 40),
    n=st.integers(2, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gate(s, d, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x, wg = _rand(ks[0], (s, d)), _rand(ks[1], (d, n), 0.5)
    p = gate_probs(x, wg)
    np.testing.assert_allclose(p, ref.gate_probs_ref(x, wg), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(jnp.sum(p, -1), 1.0, rtol=1e-5)


@given(c=st.integers(1, 1024))
def test_pick_tile_divides(c):
    t = _pick_tile(c)
    assert c % t == 0 and 1 <= t <= 128
