"""AOT pipeline: manifest/ABI consistency and HLO-text well-formedness.

The rust integration tests (rust/tests/) cover actually loading + executing
the artifacts through PJRT; here we pin the manifest contract they rely on.
"""

import json
import os

import pytest

from compile import model
from compile.configs import CONFIGS, DEFAULT_ARTIFACTS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

built = [n for n in DEFAULT_ARTIFACTS
         if os.path.exists(os.path.join(ART, n, "manifest.json"))]


@pytest.mark.skipif(not built, reason="run `make artifacts` first")
@pytest.mark.parametrize("name", built)
class TestManifest:
    def _load(self, name):
        with open(os.path.join(ART, name, "manifest.json")) as fh:
            return json.load(fh)

    def test_files_exist_and_are_hlo_text(self, name):
        man = self._load(name)
        for prog in ("init", "step", "eval"):
            path = os.path.join(ART, name, man[prog]["file"])
            assert os.path.exists(path)
            head = open(path).read(200)
            assert head.startswith("HloModule"), head[:50]

    def test_param_specs_match_model(self, name):
        man = self._load(name)
        cfg = CONFIGS[name]
        specs = model.param_specs(cfg)
        assert man["n_param_tensors"] == len(specs)
        for desc, (pname, shape) in zip(man["params"], specs):
            assert desc["name"] == pname
            assert tuple(desc["shape"]) == tuple(shape)

    def test_step_abi_counts(self, name):
        man = self._load(name)
        n = man["n_param_tensors"]
        assert len(man["step"]["inputs"]) == 3 * n + 8
        assert len(man["step"]["outputs"]) == 3 * n + 6
        assert len(man["init"]["inputs"]) == 1
        assert len(man["init"]["outputs"]) == n
        assert len(man["eval"]["outputs"]) == 5

    def test_config_consistency(self, name):
        man = self._load(name)
        cfg = CONFIGS[name]
        c = man["config"]
        assert c["p"] == cfg.p
        assert c["n_experts"] == cfg.n_experts
        assert c["capacity"] == cfg.capacity
        assert c["gate"] == cfg.gate
        assert c["dispatch"] == cfg.dispatch

    def test_counts_output_shape(self, name):
        man = self._load(name)
        cfg = CONFIGS[name]
        counts = [o for o in man["step"]["outputs"] if o["name"] == "counts"]
        assert counts and tuple(counts[0]["shape"]) == (cfg.p, cfg.n_experts)
