"""L2 correctness: gates, dispatch/capacity semantics, losses, train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS, ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["tiny4"]
N_P = len(model.param_specs(CFG))


def _mk_cfg(**over):
    base = dict(
        name="t", p=2, e_per_dev=1, layers=1, d=8, f=16, heads=2, vocab=32,
        batch=1, seq=8, k=1, cap_factor=2.0, gate="switch", dispatch="global",
        moe_every=1,
    )
    base.update(over)
    return ModelConfig(**base)


def _uniform_inputs(cfg, seed=0):
    p, n = cfg.p, cfg.n_experts
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (p, cfg.batch, cfg.seq), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=-1)
    penalty = jnp.full((p, n), float(n))
    caps = jnp.full((p, n), cfg.capacity / p)
    local = jnp.ones((p, n))
    return tokens, targets, penalty, caps, local, jnp.float32(1.0)


# ---------------------------------------------------------------------------
# Dispatch mechanics via _moe_layer directly
# ---------------------------------------------------------------------------


def _moe_inputs(cfg, seed=0):
    p, s, d, n, f = cfg.p, cfg.tokens_per_dev, cfg.d, cfg.n_experts, cfg.f
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (p, s, d))
    wg = jax.random.normal(ks[1], (d, n))
    w1 = jax.random.normal(ks[2], (n, d, f)) * 0.1
    b1 = jnp.zeros((n, f))
    w2 = jax.random.normal(ks[3], (n, f, d)) * 0.1
    b2 = jnp.zeros((n, d))
    return x, wg, w1, b1, w2, b2


class TestDispatch:
    def test_counts_conserve_tokens(self):
        cfg = _mk_cfg(p=4, seq=16)
        x, *ws = _moe_inputs(cfg)
        pen = jnp.full((4, 4), 4.0)
        caps = jnp.full((4, 4), cfg.capacity / 4)
        _, _, counts, _ = model._moe_layer(
            cfg, x, *ws, pen, caps, jnp.ones((4, 4)), jnp.float32(1.0))
        # Every (device, slot) chooses exactly k experts.
        np.testing.assert_allclose(
            np.array(counts).sum(axis=1), cfg.k * cfg.tokens_per_dev)

    def test_no_drop_when_capacity_ample(self):
        cfg = _mk_cfg(p=2, seq=8, cap_factor=4.0)
        x, *ws = _moe_inputs(cfg)
        pen = jnp.full((2, 2), 2.0)
        caps = jnp.full((2, 2), float(cfg.capacity) / 2)
        _, _, _, dropped = model._moe_layer(
            cfg, x, *ws, pen, caps, jnp.ones((2, 2)), jnp.float32(1.0))
        assert float(dropped) == 0.0

    def test_zero_caps_drop_everything_local(self):
        cfg = _mk_cfg(p=2, seq=8, dispatch="local")
        x, *ws = _moe_inputs(cfg)
        pen = jnp.full((2, 2), 2.0)
        y, _, _, dropped = model._moe_layer(
            cfg, x, *ws, pen, jnp.zeros((2, 2)), jnp.ones((2, 2)),
            jnp.float32(1.0))
        assert float(dropped) == 1.0
        np.testing.assert_allclose(np.array(y), 0.0, atol=1e-7)

    def test_local_caps_respected(self):
        # With local capacity 1 per (sender, expert), at most P tokens can
        # land in each expert buffer, and dropped > 0 for concentrated gates.
        cfg = _mk_cfg(p=2, seq=8, dispatch="local")
        x, wg, w1, b1, w2, b2 = _moe_inputs(cfg)
        x = jnp.abs(x)  # positive activations so the column bias wins
        wg = jnp.zeros_like(wg).at[:, 0].set(10.0)  # everyone wants expert 0
        pen = jnp.full((2, 2), 2.0)
        caps = jnp.ones((2, 2))
        _, _, counts, dropped = model._moe_layer(
            cfg, x, wg, w1, b1, w2, b2, pen, caps, jnp.ones((2, 2)),
            jnp.float32(1.0))
        # raw (pre-capacity) counts still show full demand on expert 0
        assert np.array(counts)[:, 0].sum() == cfg.p * cfg.tokens_per_dev
        # 16 slots demanded, 2 caps → 14/16 dropped
        np.testing.assert_allclose(float(dropped), 14.0 / 16.0, atol=1e-6)

    def test_global_cap_sender_order(self):
        # FastMoE-style: early senders win the global capacity.
        cfg = _mk_cfg(p=2, seq=8, dispatch="global")
        x, wg, w1, b1, w2, b2 = _moe_inputs(cfg)
        x = jnp.abs(x)  # positive activations so the column bias wins
        wg = jnp.zeros_like(wg).at[:, 0].set(10.0)
        pen = jnp.full((2, 2), 2.0)
        caps = jnp.full((2, 2), 4.0)  # global cap per expert = min(8, C)
        y, _, _, dropped = model._moe_layer(
            cfg, x, wg, w1, b1, w2, b2, pen, caps, jnp.ones((2, 2)),
            jnp.float32(1.0))
        # expert 0 takes 8 of 16 slots: sender 0 fully served, sender 1 dropped
        y = np.array(y)
        assert np.abs(y[0]).sum() > 0
        np.testing.assert_allclose(y[1], 0.0, atol=1e-7)

    def test_gshard_two_experts_per_token(self):
        cfg = _mk_cfg(p=2, seq=8, gate="gshard", k=2, cap_factor=4.0)
        x, *ws = _moe_inputs(cfg)
        pen = jnp.full((2, 2), 2.0)
        caps = jnp.full((2, 2), float(cfg.capacity) / 2)
        _, _, counts, _ = model._moe_layer(
            cfg, x, *ws, pen, caps, jnp.ones((2, 2)), jnp.float32(1.0))
        np.testing.assert_allclose(
            np.array(counts).sum(axis=1), 2 * cfg.tokens_per_dev)


class TestHirGate:
    def _probs(self, cfg, seed=1):
        x, wg, *_ = _moe_inputs(cfg, seed)
        from compile.kernels import gate_probs
        p, s, d = x.shape
        return model.gate_probs(x.reshape(p * s, d), wg).reshape(p, s, -1) \
            if False else gate_probs(x.reshape(p * s, d), wg).reshape(p, s, cfg.n_experts)

    def test_zero_budget_forces_local(self):
        cfg = _mk_cfg(p=4, seq=8, gate="hir")
        probs = self._probs(cfg)
        # devices 0,1 on node 0 own experts 0,1; devices 2,3 own 2,3
        local = jnp.zeros((4, 4)).at[:2, :2].set(1.0).at[2:, 2:].set(1.0)
        idx, _ = model._select_experts(cfg, probs, local, jnp.float32(0.0))
        idx = np.array(idx)[..., 0]
        lm = np.array(local)
        for i in range(4):
            assert all(lm[i, e] == 1.0 for e in idx[i])

    def test_full_budget_is_plain_top1(self):
        cfg = _mk_cfg(p=4, seq=8, gate="hir")
        probs = self._probs(cfg)
        local = jnp.zeros((4, 4)).at[:2, :2].set(1.0).at[2:, 2:].set(1.0)
        idx, _ = model._select_experts(cfg, probs, local, jnp.float32(1.0))
        np.testing.assert_array_equal(
            np.array(idx)[..., 0], np.array(jnp.argmax(probs, -1)))

    def test_budget_limits_remote_count(self):
        cfg = _mk_cfg(p=4, seq=8, gate="hir")
        probs = self._probs(cfg, seed=3)
        local = jnp.zeros((4, 4)).at[:2, :2].set(1.0).at[2:, 2:].set(1.0)
        frac = 0.25  # budget = 2 of 8 tokens
        idx, _ = model._select_experts(cfg, probs, local, jnp.float32(frac))
        idx = np.array(idx)[..., 0]
        lm = np.array(local)
        for i in range(4):
            remote = sum(1 for e in idx[i] if lm[i, e] == 0.0)
            assert remote <= 2


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


class TestAuxLoss:
    def test_uniform_penalty_is_eq1(self):
        # With penalty = N and a perfectly balanced dispatch, the aux loss
        # equals N * Σ_e m_e * f_e = N * N * (1/N) * (1/N) = 1.
        cfg = _mk_cfg(p=2, seq=8)
        x, wg, w1, b1, w2, b2 = _moe_inputs(cfg)
        wg = jnp.zeros_like(wg)  # uniform probs
        # alternate tokens between experts via x? easier: uniform probs give
        # m = 1/N; force counts balanced by alternating argmax tie-break —
        # with all-equal probs argmax picks expert 0, so set tiny bias.
        x = x.at[:, ::2, :].set(x[:, ::2, :] + 0.0)
        wg = wg.at[0, 0].set(0.0)
        pen = jnp.full((2, 2), 2.0)
        caps = jnp.full((2, 2), float(cfg.capacity) / 2)
        _, aux, counts, _ = model._moe_layer(
            cfg, x, wg, w1, b1, w2, b2, pen, caps, jnp.ones((2, 2)),
            jnp.float32(1.0))
        m = 0.5  # uniform over 2 experts
        f = np.array(counts) / cfg.tokens_per_dev
        want = np.mean((2.0 * m * f).sum(axis=1))
        np.testing.assert_allclose(float(aux), want, rtol=1e-5)

    def test_penalty_steers_gradient(self):
        # Raising the penalty on expert 1 must push the gate's gradient
        # toward expert 0 — the core Eq. 8 mechanism.
        cfg = _mk_cfg(p=2, seq=8)
        x, wg, w1, b1, w2, b2 = _moe_inputs(cfg)
        caps = jnp.full((2, 2), float(cfg.capacity) / 2)
        local = jnp.ones((2, 2))

        def aux_of(wg_, pen):
            _, aux, _, _ = model._moe_layer(
                cfg, x, wg_, w1, b1, w2, b2, pen, caps, local,
                jnp.float32(1.0))
            return aux

        pen_skew = jnp.array([[1.0, 8.0], [1.0, 8.0]])
        g = jax.grad(aux_of)(wg, pen_skew)
        # one descent step on the skewed loss must shift gate mass away
        # from the heavily-penalised expert 1 toward expert 0
        from compile.kernels import gate_probs
        def mean_probs(wg_):
            p_, s_, d_ = x.shape
            probs = gate_probs(x.reshape(p_ * s_, d_), wg_)
            return np.array(jnp.mean(probs, axis=0))
        before = mean_probs(wg)
        after = mean_probs(wg - 0.5 * g)
        assert after[1] < before[1], (before, after)
        assert after[0] > before[0], (before, after)


# ---------------------------------------------------------------------------
# Full model / train step
# ---------------------------------------------------------------------------


class TestTrainStep:
    def test_loss_decreases(self):
        cfg = CFG
        params = model.init_params(cfg, 0)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        ins = _uniform_inputs(cfg)
        step = jax.jit(lambda *f: model.train_step(cfg, N_P, *f))
        state = list(params) + m + v
        losses = []
        t = jnp.float32(0)
        for i in range(8):
            out = step(*state, t, jnp.float32(3e-3), *ins)
            state = list(out[: 3 * N_P])
            t = out[3 * N_P]
            losses.append(float(out[3 * N_P + 1]))
        assert losses[-1] < losses[0], losses

    def test_deterministic(self):
        cfg = CFG
        params = model.init_params(cfg, 0)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        ins = _uniform_inputs(cfg)
        step = jax.jit(lambda *f: model.train_step(cfg, N_P, *f))
        o1 = step(*params, *m, *v, jnp.float32(0), jnp.float32(1e-3), *ins)
        o2 = step(*params, *m, *v, jnp.float32(0), jnp.float32(1e-3), *ins)
        np.testing.assert_array_equal(np.array(o1[3 * N_P + 1]),
                                      np.array(o2[3 * N_P + 1]))

    def test_init_deterministic_in_seed(self):
        a = model.init_params(CFG, 7)
        b = model.init_params(CFG, 7)
        c = model.init_params(CFG, 8)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.array(x), np.array(y))
        assert any(not np.array_equal(np.array(x), np.array(y))
                   for x, y in zip(a, c))

    def test_eval_matches_forward(self):
        cfg = CFG
        params = model.init_params(cfg, 0)
        ins = _uniform_inputs(cfg)
        loss, ce, aux, counts, dropped = model.eval_step(
            cfg, N_P, *params, *ins)
        want, (wce, waux, wcounts, wdrop) = model.forward(cfg, params, *ins)
        np.testing.assert_allclose(float(loss), float(want), rtol=1e-6)
        np.testing.assert_allclose(np.array(counts), np.array(wcounts))

    def test_param_specs_cover_all_layers(self):
        for name in ("tiny4", "small8_switch", "small8_gshard"):
            cfg = CONFIGS[name]
            specs = model.param_specs(cfg)
            names = [s for s, _ in specs]
            assert len(names) == len(set(names))
            moe = cfg.moe_layer_ids()
            for l in range(cfg.layers):
                if l in moe:
                    assert f"l{l}.wg" in names
                else:
                    assert f"l{l}.ffn_w1" in names

    @pytest.mark.parametrize("name", ["tiny4", "small8_switch"])
    def test_capacity_positive_and_rounded(self, name):
        cfg = CONFIGS[name]
        assert cfg.capacity > 0 and cfg.capacity % 8 == 0
        assert cfg.capacity * cfg.n_experts >= cfg.k * cfg.tokens_per_dev * cfg.p
