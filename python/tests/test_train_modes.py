"""Short real training loops per gate/dispatch mode.

Each mode that ships as an artifact must train without NaNs and decrease
its loss on a learnable stream — the python-side counterpart of the rust
integration tests (which only exercise the tiny4 artifact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig

jax.config.update("jax_platform_name", "cpu")


def _cfg(gate, dispatch, k):
    return ModelConfig(
        name="t", p=2, e_per_dev=1, layers=2, d=16, f=32, heads=2, vocab=64,
        batch=1, seq=16, k=k, cap_factor=2.0, gate=gate, dispatch=dispatch,
        moe_every=1,
    )


def _run(cfg, steps=6, lr=5e-3, local=None, frac=1.0):
    n = len(model.param_specs(cfg))
    params = model.init_params(cfg, 0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    p_, n_e = cfg.p, cfg.n_experts
    key = jax.random.PRNGKey(0)
    # learnable stream: repeated short pattern
    base = jax.random.randint(key, (cfg.batch, cfg.seq + 1), 0, 8)
    tokens = jnp.tile(base[None, :, : cfg.seq], (p_, 1, 1))
    targets = jnp.tile(base[None, :, 1:], (p_, 1, 1))
    penalty = jnp.full((p_, n_e), float(n_e))
    caps = jnp.full((p_, n_e), cfg.capacity / p_)
    local = jnp.ones((p_, n_e)) if local is None else local
    step = jax.jit(lambda *f: model.train_step(cfg, n, *f))
    state = list(params) + m + v
    t = jnp.float32(0)
    losses = []
    for _ in range(steps):
        out = step(*state, t, jnp.float32(lr), tokens, targets, penalty, caps,
                   local, jnp.float32(frac))
        state = list(out[: 3 * n])
        t = out[3 * n]
        losses.append(float(out[3 * n + 1]))
    return losses


@pytest.mark.parametrize("gate,dispatch,k", [
    ("switch", "global", 1),
    ("switch", "local", 1),
    ("gshard", "local", 2),
    ("gshard", "global", 2),
])
def test_mode_trains(gate, dispatch, k):
    losses = _run(_cfg(gate, dispatch, k))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses


def test_hir_trains_with_node_structure():
    cfg = _cfg("hir", "global", 1)
    local = jnp.zeros((2, 2)).at[0, 0].set(1.0).at[1, 1].set(1.0)
    losses = _run(cfg, local=local, frac=0.5)
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses


def test_hir_zero_budget_still_trains():
    cfg = _cfg("hir", "global", 1)
    local = jnp.zeros((2, 2)).at[0, 0].set(1.0).at[1, 1].set(1.0)
    losses = _run(cfg, local=local, frac=0.0)
    assert all(np.isfinite(losses)), losses
