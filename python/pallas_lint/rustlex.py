"""A lightweight rust lexer: just enough to analyze, never to compile.

Produces a flat token stream with line numbers, the comment list (for
allow directives), and lexical errors (unterminated strings/comments —
surfaced by the ``structure`` rule). Comments and string/char literal
*contents* never appear in the token stream, so a ``HashMap`` mentioned
in a doc comment or a format string can never trip a rule.

Handled rust lexical forms: line + nested block comments, string
literals with escapes, raw (byte) strings ``r#".."#`` at any hash
depth, byte strings, char literals vs lifetimes, identifiers, numbers,
and single-char punctuation.
"""

from __future__ import annotations

from typing import List, NamedTuple, Tuple

IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
IDENT_CONT = IDENT_START | set("0123456789")
DIGITS = set("0123456789")


class Tok(NamedTuple):
    kind: str  # "ident" | "num" | "str" | "char" | "life" | "punct"
    text: str
    line: int


class Comment(NamedTuple):
    line: int  # line the comment starts on
    text: str  # comment body without the // or /* */ fences


class LexError(NamedTuple):
    line: int
    msg: str


def lex(src: str) -> Tuple[List[Tok], List[Comment], List[LexError]]:
    toks: List[Tok] = []
    comments: List[Comment] = []
    errors: List[LexError] = []
    i, n, line = 0, len(src), 1

    def bump_lines(text: str) -> None:
        nonlocal line
        line += text.count("\n")

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # comments
        if src.startswith("//", i):
            end = src.find("\n", i)
            end = n if end == -1 else end
            comments.append(Comment(line, src[i + 2 : end]))
            i = end
            continue
        if src.startswith("/*", i):
            start_line = line
            depth, j = 1, i + 2
            while j < n and depth > 0:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    if src[j] == "\n":
                        line += 1
                    j += 1
            if depth > 0:
                errors.append(LexError(start_line, "unterminated block comment"))
            comments.append(Comment(start_line, src[i + 2 : max(i + 2, j - 2)]))
            i = j
            continue
        # raw strings r".."  r#".."#  br#".."# (any hash depth)
        if c in "rb":
            j = i
            if src[j] == "b":
                j += 1
            if j < n and src[j] == "r":
                k = j + 1
                hashes = 0
                while k < n and src[k] == "#":
                    hashes += 1
                    k += 1
                if k < n and src[k] == '"':
                    close = '"' + "#" * hashes
                    end = src.find(close, k + 1)
                    if end == -1:
                        errors.append(LexError(line, "unterminated raw string"))
                        i = n
                        continue
                    toks.append(Tok("str", src[k + 1 : end], line))
                    bump_lines(src[i : end + len(close)])
                    i = end + len(close)
                    continue
        # plain / byte strings
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            j = i + (2 if c == "b" else 1)
            start_line = line
            buf = []
            while j < n and src[j] != '"':
                if src[j] == "\\" and j + 1 < n:
                    if src[j + 1] == "\n":
                        line += 1
                    buf.append(src[j : j + 2])
                    j += 2
                    continue
                if src[j] == "\n":
                    line += 1
                buf.append(src[j])
                j += 1
            if j >= n:
                errors.append(LexError(start_line, "unterminated string literal"))
            toks.append(Tok("str", "".join(buf), start_line))
            i = j + 1
            continue
        # char literal vs lifetime
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2
                if j < n and src[j] == "\n":
                    line += 1
                j += 1
                # \u{...} and multi-char escapes: scan to the closing quote
                while j < n and src[j] != "'":
                    j += 1
                if j >= n:
                    errors.append(LexError(line, "unterminated char literal"))
                toks.append(Tok("char", src[i + 1 : j], line))
                i = j + 1
                continue
            if i + 2 < n and src[i + 2] == "'":
                toks.append(Tok("char", src[i + 1], line))
                i += 3
                continue
            # lifetime: 'ident (no closing quote)
            j = i + 1
            while j < n and src[j] in IDENT_CONT:
                j += 1
            toks.append(Tok("life", src[i + 1 : j], line))
            i = j
            continue
        # identifiers
        if c in IDENT_START:
            j = i + 1
            while j < n and src[j] in IDENT_CONT:
                j += 1
            toks.append(Tok("ident", src[i:j], line))
            i = j
            continue
        # numbers (no '.' so range expressions like 0..p stay punctuation)
        if c in DIGITS:
            j = i + 1
            while j < n and src[j] in IDENT_CONT:
                j += 1
            toks.append(Tok("num", src[i:j], line))
            i = j
            continue
        toks.append(Tok("punct", c, line))
        i += 1

    return toks, comments, errors
