"""CLI: ``python -m pallas_lint [paths] [options]``.

Zero findings → exit 0. Designed for containers with no rust
toolchain: the analyzer is stdlib-only python.

Examples (from the repo root, ``PYTHONPATH=python``)::

    python -m pallas_lint rust/src                 # full rule set
    python -m pallas_lint --only structure rust/tests benches examples
    python -m pallas_lint --list-registry          # mirror coverage map
    python -m pallas_lint --write-baseline rust/src
    python -m pallas_lint --update-fingerprints
"""

from __future__ import annotations

import argparse
import sys

from . import __version__, rules_mirror, rules_ratchet
from .runner import ALL_RULES, find_repo_root, run_lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="pallas_lint",
        description="toolchain-free static analysis for the ta_moe crate",
    )
    ap.add_argument("paths", nargs="*", default=["rust/src"], help="files or directories to scan")
    ap.add_argument(
        "--only",
        action="append",
        metavar="RULE",
        help=f"run only these rule families (repeatable; one of {sorted(ALL_RULES)})",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="regenerate panic_baseline.json from the scanned files",
    )
    ap.add_argument(
        "--update-fingerprints",
        action="store_true",
        help="refresh mirror_registry.json fingerprints after re-validating mirrors",
    )
    ap.add_argument(
        "--list-registry",
        action="store_true",
        help="print the mirror-coverage registry and exit",
    )
    ap.add_argument("--version", action="version", version=f"pallas-lint {__version__}")
    args = ap.parse_args(argv)

    if args.list_registry:
        entries = rules_mirror.load_registry()
        subsystems = sorted({e["subsystem"] for e in entries})
        print(f"mirror-coverage registry: {len(entries)} entries, "
              f"{len(subsystems)} subsystems")
        for e in entries:
            print(f"  [{e['subsystem']}] {e['rust_file']}::{e['rust_fn']}"
                  f"  ->  {e['mirror_file']}::{e['mirror_symbol']}")
        return 0

    rules = set(args.only) if args.only else None
    findings, files = run_lint(
        args.paths, rules=rules, update_fingerprints=args.update_fingerprints
    )

    if args.write_baseline:
        rules_ratchet.write_baseline(files)
        print(f"pallas-lint: wrote panic baseline for {len(files)} files")
        # re-run so the exit status reflects the fresh baseline
        findings, _ = run_lint(args.paths, rules=rules)

    for f in findings:
        print(f.render())
    n = len(findings)
    scanned = len(files)
    label = "finding" if n == 1 else "findings"
    print(f"pallas-lint: {n} {label} in {scanned} files "
          f"({', '.join(sorted(rules or ALL_RULES))})")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
