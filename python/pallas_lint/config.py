"""Rule configuration: what counts as priced, canonical, or forbidden.

Everything here is policy, not mechanism — the rule implementations
live in ``rules_*.py``. Keep this file the single place a reviewer has
to read to know what the linter enforces.
"""

from __future__ import annotations

# Directories under rust/src whose code makes or prices decisions that
# must be bit-reproducible across runs and machines. Wall clocks and
# ambient RNG are forbidden here (determinism rule); unordered
# collections are forbidden everywhere.
PRICED_DIRS = {
    "comm",
    "coordinator",
    "placement",
    "overlap",
    "serve",
    "dispatch",
    "perturb",
    "trace",
    "analyze",
}

# Unordered std collections: iteration order varies per *instance*
# (RandomState), so any fold/emission over them is nondeterministic.
# BTreeMap/BTreeSet are the sanctioned replacements.
UNORDERED_TYPES = {"HashMap", "HashSet"}

# Wall-clock and ambient-RNG identifiers forbidden in PRICED_DIRS.
WALL_CLOCKS = {"Instant", "SystemTime"}
AMBIENT_RNG = {"thread_rng", "ThreadRng", "from_entropy", "OsRng"}

# Canonical unit suffixes (ROADMAP standing constraint: every priced
# quantity names its unit). Used by the metrics schema check.
CANONICAL_SUFFIXES = ("_s", "_bytes", "_gbps", "_us", "_rps", "_flops")

# Non-canonical unit spellings: a field/fn/key ending in one of these
# drifts from the repo convention (seconds are `_s`, bytes `_bytes`,
# bandwidth `_gbps`). Checked on struct fields, fn names, and
# summary-JSON keys. Order matters: longest match wins over `_s`.
FORBIDDEN_SUFFIXES = (
    "_secs",
    "_seconds",
    "_sec",
    "_millis",
    "_ms",
    "_mins",
    "_nanos",
    "_ns",
    "_byte",
    "_kb",
    "_mb",
    "_gb",
    "_bps",
    "_mbps",
    "_gbit",
)

# metrics/mod.rs CSV schema: columns that do not literally equal their
# StepRecord source field. Everything else must match the field name
# exactly or be the field name minus the `sim_` prefix.
CSV_ALIASES = {
    "plan_hit": "plan_cached",  # bool emitted as 0/1
    "sim_t": "t",  # cumulative time axis local, not a record field
}

# StepRecord fields intentionally absent from the CSV row.
CSV_SKIPPED_FIELDS = {"wall_s"}

# Mirror registry: the priced subsystems that must stay covered. The
# registry json may add entries but can never drop below this set.
REQUIRED_SUBSYSTEMS = {
    "comm-pricing",
    "bvn-refinement",
    "placement-gate",
    "overlap-autotune",
    "serve-cache",
    "serve-batcher",
    "perturb-recovery",
    "trace-utilization",
    "whatif-pricing",
}

# MetricsRegistry key grammar (trace/registry.rs): counter keys end in
# `_total`; gauge keys carry a canonical unit suffix. Checked at every
# call site of these registry methods so a drifting key is caught where
# it is written, not when a dashboard misreads it.
REGISTRY_COUNTER_METHODS = {"inc", "counter"}
REGISTRY_GAUGE_METHODS = {"gauge_add", "gauge"}
COUNTER_SUFFIX = "_total"

# Inline allow directive, written in a comment on the finding's line or
# the line directly above it:
#
#   // pallas-lint: allow(determinism) -- <justification, >= 10 chars>
#
# A directive without a justification is itself a finding (allowlist
# rule): every exception must say why, inline, where reviewers read it.
DIRECTIVE_MARKER = "pallas-lint:"
MIN_JUSTIFICATION = 10
