"""Rule family: units.

The repo's naming convention (DESIGN.md): every quantity carries its
unit as a suffix — seconds are ``_s``, byte counts ``_bytes``,
bandwidths ``_gbps`` (with ``_us``/``_rps``/``_flops`` where natural).
Two checks enforce it:

* **naming** — struct fields and fn names must not use drifting unit
  spellings (``_ms``, ``_secs``, ``_byte``, ``_gb``, …). One spelling
  per unit keeps CSV columns, JSON keys, and code greppable as one
  vocabulary.
* **registry keys** — every ``MetricsRegistry`` call site must use the
  key grammar: counters (``.inc``/``.counter``) end in ``_total``,
  gauges (``.gauge_add``/``.gauge``) end in a canonical unit suffix.
  One vocabulary across traces, summaries, and dashboards.
* **metrics schema** — ``metrics/mod.rs`` must declare the CSV schema
  as machine-checkable consts (``CSV_HEADER`` + ``CSV_SCHEMA``
  column→field pairs). The header, the schema, the ``StepRecord``
  field order, and the actual ``write_csv`` row emission are
  cross-checked token-by-token, so a column can no longer drift from
  the field it claims to print — the bug class PRs 2–6 guarded against
  by hand.
"""

from __future__ import annotations

import re
from typing import List, Optional, Tuple

from . import config
from .findings import Finding
from .items import SourceFile, all_struct_fields, fn_names, fn_token_span, struct_fields

SNAKE_RE = re.compile(r"^[a-z][a-z0-9_]*$")


def _forbidden_suffix(name: str) -> Optional[str]:
    for suf in config.FORBIDDEN_SUFFIXES:
        if name.endswith(suf):
            return suf
    return None


def _const_str(sf: SourceFile, const_name: str) -> Optional[Tuple[str, int]]:
    toks = sf.toks
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == const_name and i >= 1:
            if toks[i - 1].kind == "ident" and toks[i - 1].text == "const":
                for j in range(i + 1, min(i + 12, len(toks))):
                    if toks[j].kind == "str":
                        return toks[j].text, toks[j].line
    return None


def _const_str_pairs(sf: SourceFile, const_name: str) -> Optional[Tuple[List[Tuple[str, str]], int]]:
    toks = sf.toks
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == const_name and i >= 1:
            if toks[i - 1].kind == "ident" and toks[i - 1].text == "const":
                strs: List[str] = []
                j = i + 1
                while j < len(toks) and toks[j].text != ";":
                    if toks[j].kind == "str":
                        strs.append(toks[j].text)
                    j += 1
                pairs = list(zip(strs[0::2], strs[1::2]))
                return pairs, t.line
    return None


def _unescape_header(raw: str) -> str:
    # a `\` before a newline is rust's string continuation: it swallows
    # the newline and leading whitespace of the next line
    return re.sub(r"\\\n\s*", "", raw)


def _field_refs_in_fn(sf: SourceFile, fn: str, receiver: str = "r") -> List[str]:
    span = fn_token_span(sf, fn)
    if span is None:
        return []
    toks = sf.toks
    refs: List[str] = []
    for k in range(span[0], span[1] - 1):
        if (
            toks[k].kind == "ident"
            and toks[k].text == receiver
            and toks[k + 1].text == "."
            and toks[k + 2].kind == "ident"
        ):
            refs.append(toks[k + 2].text)
    return refs


def _col_matches_field(col: str, field: str) -> bool:
    if config.CSV_ALIASES.get(col) == field:
        return True
    return field == col or field == "sim_" + col


def _suffixes_agree(col: str, field: str) -> bool:
    if config.CSV_ALIASES.get(col) == field:
        return True  # documented aliases own their naming
    for suf in config.CANONICAL_SUFFIXES:
        if col.endswith(suf) != field.endswith(suf):
            return False
    return True


def check(sf: SourceFile) -> List[Finding]:
    out: List[Finding] = []

    # -- naming: one spelling per unit, everywhere ---------------------
    for name, line in all_struct_fields(sf):
        suf = _forbidden_suffix(name)
        if suf and not sf.allowed(line, "units"):
            out.append(
                Finding(
                    sf.relpath,
                    line,
                    "units",
                    f"field `{name}` uses non-canonical unit suffix "
                    f"`{suf}` (canonical: {', '.join(config.CANONICAL_SUFFIXES)})",
                )
            )
    for name, line, _pub in fn_names(sf):
        suf = _forbidden_suffix(name)
        if suf and not sf.allowed(line, "units"):
            out.append(
                Finding(
                    sf.relpath,
                    line,
                    "units",
                    f"fn `{name}` uses non-canonical unit suffix `{suf}`",
                )
            )

    # -- registry key grammar ------------------------------------------
    out.extend(_check_registry_keys(sf))

    # -- metrics CSV/JSON schema ---------------------------------------
    if sf.relpath.replace("\\", "/").endswith("metrics/mod.rs"):
        out.extend(_check_metrics_schema(sf))
    return out


def _check_registry_keys(sf: SourceFile) -> List[Finding]:
    """Enforce the MetricsRegistry key grammar at every call site: a
    string literal passed to ``.inc(``/``.counter(`` must end in
    ``_total``; one passed to ``.gauge_add(``/``.gauge(`` must end in a
    canonical unit suffix. Both must be snake_case."""
    out: List[Finding] = []
    toks = sf.toks
    methods = config.REGISTRY_COUNTER_METHODS | config.REGISTRY_GAUGE_METHODS
    for k in range(1, len(toks) - 2):
        if (
            toks[k].kind != "ident"
            or toks[k].text not in methods
            or toks[k - 1].text != "."
            or toks[k + 1].text != "("
            or toks[k + 2].kind != "str"
        ):
            continue
        method = toks[k].text
        key, line = toks[k + 2].text, toks[k + 2].line
        if sf.allowed(line, "units"):
            continue
        if not SNAKE_RE.match(key):
            out.append(
                Finding(
                    sf.relpath,
                    line,
                    "units",
                    f"registry key `{key}` is not snake_case",
                )
            )
        elif method in config.REGISTRY_COUNTER_METHODS:
            if not key.endswith(config.COUNTER_SUFFIX):
                out.append(
                    Finding(
                        sf.relpath,
                        line,
                        "units",
                        f"registry counter key `{key}` must end in "
                        f"`{config.COUNTER_SUFFIX}` (`.{method}` call)",
                    )
                )
        elif not key.endswith(config.CANONICAL_SUFFIXES):
            out.append(
                Finding(
                    sf.relpath,
                    line,
                    "units",
                    f"registry gauge key `{key}` must end in a canonical "
                    f"unit suffix ({', '.join(config.CANONICAL_SUFFIXES)}) "
                    f"(`.{method}` call)",
                )
            )
    return out


def _check_metrics_schema(sf: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    header = _const_str(sf, "CSV_HEADER")
    schema = _const_str_pairs(sf, "CSV_SCHEMA")
    if header is None or schema is None:
        out.append(
            Finding(
                sf.relpath,
                1,
                "units",
                "metrics module must declare `CSV_HEADER` and "
                "`CSV_SCHEMA` consts (the machine-checkable CSV schema)",
            )
        )
        return out
    header_raw, header_line = header
    pairs, schema_line = schema
    cols = _unescape_header(header_raw).split(",")

    if cols != [c for c, _ in pairs]:
        out.append(
            Finding(
                sf.relpath,
                header_line,
                "units",
                f"CSV_HEADER columns {cols} do not match CSV_SCHEMA "
                f"columns {[c for c, _ in pairs]}",
            )
        )

    fields = [f for f, _ in struct_fields(sf, "StepRecord")]
    for col, field in pairs:
        if not _col_matches_field(col, field):
            out.append(
                Finding(
                    sf.relpath,
                    schema_line,
                    "units",
                    f"CSV column `{col}` maps to `{field}`, which is "
                    "neither the field name, `sim_`+column, nor a "
                    "declared alias",
                )
            )
        if not _suffixes_agree(col, field):
            out.append(
                Finding(
                    sf.relpath,
                    schema_line,
                    "units",
                    f"CSV column `{col}` and source field `{field}` "
                    "disagree on unit suffix",
                )
            )
        if field != "t" and field not in fields:
            out.append(
                Finding(
                    sf.relpath,
                    schema_line,
                    "units",
                    f"CSV_SCHEMA references `{field}`, not a StepRecord field",
                )
            )

    # schema field order must follow StepRecord declaration order, and
    # every record field is either emitted or explicitly skipped
    schema_fields = [f for _, f in pairs if f != "t"]
    idx = {f: i for i, f in enumerate(fields)}
    positions = [idx[f] for f in schema_fields if f in idx]
    if positions != sorted(positions):
        out.append(
            Finding(
                sf.relpath,
                schema_line,
                "units",
                "CSV column order does not follow StepRecord field order",
            )
        )
    for f in fields:
        if f not in schema_fields and f not in config.CSV_SKIPPED_FIELDS:
            out.append(
                Finding(
                    sf.relpath,
                    schema_line,
                    "units",
                    f"StepRecord field `{f}` is missing from CSV_SCHEMA "
                    "(add it or list it in CSV_SKIPPED_FIELDS)",
                )
            )

    # the row actually written must be the schema, in order
    refs = _field_refs_in_fn(sf, "write_csv")
    if refs != schema_fields:
        out.append(
            Finding(
                sf.relpath,
                schema_line,
                "units",
                f"write_csv emits fields {refs} but CSV_SCHEMA declares "
                f"{schema_fields}",
            )
        )

    # summary-JSON keys: snake_case, canonical unit vocabulary
    span = fn_token_span(sf, "summary_json")
    if span is not None:
        toks = sf.toks
        for k in range(span[0], span[1] - 3):
            if (
                toks[k].kind == "ident"
                and toks[k].text == "insert"
                and toks[k + 1].text == "("
                and toks[k + 2].kind == "str"
            ):
                key, line = toks[k + 2].text, toks[k + 2].line
                if not SNAKE_RE.match(key):
                    out.append(
                        Finding(
                            sf.relpath,
                            line,
                            "units",
                            f"summary-JSON key `{key}` is not snake_case",
                        )
                    )
                suf = _forbidden_suffix(key)
                if suf and not sf.allowed(line, "units"):
                    out.append(
                        Finding(
                            sf.relpath,
                            line,
                            "units",
                            f"summary-JSON key `{key}` uses non-canonical "
                            f"unit suffix `{suf}`",
                        )
                    )
    return out
