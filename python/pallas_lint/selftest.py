"""pallas-lint's own test suite (stdlib unittest, no toolchain).

Fixture rust snippets with known violations pin every rule family's
behavior — what fires, what the allowlist suppresses, and the exact
golden findings — plus the end-to-end acceptance run: the real crate
must lint clean with the checked-in baseline and registry.

Run ``python3 -m pallas_lint.selftest`` (CI `static-analysis` job).
"""

from __future__ import annotations

import json
import os
import tempfile
import unittest

from . import rules_determinism, rules_mirror, rules_ratchet, rules_structure, rules_units
from .items import SourceFile, fn_fingerprint, fn_names, struct_fields
from .runner import find_repo_root, run_lint
from .rustlex import lex

REPO = find_repo_root(os.path.dirname(__file__))


def sf(src: str, relpath: str = "rust/src/comm/fixture.rs") -> SourceFile:
    return SourceFile(relpath, src)


class LexerTest(unittest.TestCase):
    def kinds(self, src):
        toks, _, errs = lex(src)
        self.assertEqual(errs, [])
        return [(t.kind, t.text) for t in toks]

    def test_strings_comments_chars_lifetimes(self):
        src = r'''
// line comment with HashMap
/* block /* nested */ still comment Instant */
let s = "str with } brace \" esc";
let r = r#"raw "with" quotes }"#;
let b = b"bytes";
let c = '}';
let esc = '\n';
let lt: &'static str = "x";
'''
        toks = self.kinds(src)
        # no comment text leaks into the identifier stream
        self.assertNotIn(("ident", "HashMap"), toks)
        self.assertNotIn(("ident", "Instant"), toks)
        # braces inside strings/chars don't count as delimiters
        f = sf(src)
        self.assertEqual(rules_structure.check_file(f), [])
        # char vs lifetime disambiguation
        self.assertIn(("char", "}"), toks)
        self.assertIn(("char", "\\n"), toks)
        self.assertIn(("life", "static"), toks)

    def test_unbalanced_delimiters_are_findings(self):
        f = sf("fn broken() { let x = (1 + 2; }\n")
        found = rules_structure.check_file(f)
        self.assertEqual(len(found), 1)
        self.assertEqual(found[0].rule, "structure")
        f = sf("fn unclosed() { let v = vec![1, 2;\n")
        self.assertTrue(rules_structure.check_file(f))


class DeterminismTest(unittest.TestCase):
    def test_unordered_types_flagged_everywhere(self):
        f = sf("use std::collections::HashMap;\n", "rust/src/util/x.rs")
        found = rules_determinism.check(f)
        self.assertEqual([x.rule for x in found], ["determinism"])
        self.assertIn("HashMap", found[0].msg)

    def test_wall_clock_only_in_priced_dirs(self):
        src = "fn t() -> f64 { Instant::now().elapsed().as_secs_f64() }\n"
        self.assertTrue(rules_determinism.check(sf(src, "rust/src/comm/x.rs")))
        self.assertTrue(rules_determinism.check(sf(src, "rust/src/serve/x.rs")))
        # util is a harness, not a priced module
        self.assertEqual(rules_determinism.check(sf(src, "rust/src/util/x.rs")), [])

    def test_ambient_rng_flagged(self):
        f = sf("let mut rng = thread_rng();\n", "rust/src/dispatch/x.rs")
        found = rules_determinism.check(f)
        self.assertEqual(len(found), 1)
        self.assertIn("thread_rng", found[0].msg)

    def test_allow_directive_suppresses_with_justification(self):
        src = (
            "// pallas-lint: allow(determinism) -- wall_s observability only\n"
            "let t0 = std::time::Instant::now();\n"
        )
        f = sf(src, "rust/src/coordinator/x.rs")
        self.assertEqual(rules_determinism.check(f), [])
        self.assertEqual(f.directive_findings, [])

    def test_unjustified_directive_is_a_finding(self):
        src = (
            "// pallas-lint: allow(determinism)\n"
            "let t0 = std::time::Instant::now();\n"
        )
        f = sf(src, "rust/src/coordinator/x.rs")
        self.assertEqual([x.rule for x in f.directive_findings], ["allowlist"])
        # and it does NOT suppress: the exception is unjustified
        self.assertTrue(rules_determinism.check(f))

    def test_doc_comment_mentions_never_fire(self):
        f = sf("//! A naive `HashMap` oracle lives in tests.\nfn f() {}\n")
        self.assertEqual(rules_determinism.check(f), [])


class UnitsTest(unittest.TestCase):
    def test_forbidden_suffixes_on_fields_and_fns(self):
        src = (
            "pub struct S {\n"
            "    pub latency_ms: f64,\n"
            "    pub window_s: f64,\n"
            "}\n"
            "pub fn poll_secs() -> f64 { 0.0 }\n"
            "pub fn poll_s() -> f64 { 0.0 }\n"
        )
        found = rules_units.check(sf(src))
        msgs = sorted(x.msg for x in found)
        self.assertEqual(len(found), 2, msgs)
        self.assertIn("latency_ms", msgs[0])
        self.assertIn("poll_secs", msgs[1])

    def test_registry_key_grammar(self):
        src = (
            "fn f(tr: &mut Tracer) {\n"
            '    tr.registry_mut().inc("plan_hits_total", 1);\n'
            '    tr.registry_mut().inc("plan_hits", 1);\n'
            '    tr.registry_mut().gauge_add("fetch_s", 0.5);\n'
            '    tr.registry_mut().gauge_add("fetch_time", 0.5);\n'
            '    tr.registry_mut().gauge_add("Bad-Key_s", 0.5);\n'
            "    let _ = reg.counter(\"plan_hits_total\");\n"
            "}\n"
        )
        found = rules_units.check(sf(src, "rust/src/trace/fixture.rs"))
        msgs = sorted(x.msg for x in found)
        self.assertEqual(len(found), 3, msgs)
        self.assertIn("plan_hits", msgs[0])
        self.assertIn("_total", msgs[0])
        self.assertIn("fetch_time", msgs[1])
        self.assertIn("canonical", msgs[1])
        self.assertIn("Bad-Key_s", msgs[2])
        self.assertIn("not snake_case", msgs[2])
        # the allow directive works here like everywhere else
        allowed = (
            "// pallas-lint: allow(units) -- external dashboard owns this name\n"
            'fn g(tr: &mut Tracer) { tr.registry_mut().inc("legacy_count", 1); }\n'
        )
        f = sf(allowed, "rust/src/trace/fixture.rs")
        self.assertEqual(rules_units.check(f), [])

    def test_metrics_file_requires_schema_consts(self):
        f = sf("pub struct StepRecord { pub a: f64 }\n", "rust/src/metrics/mod.rs")
        found = rules_units.check(f)
        self.assertTrue(any("CSV_HEADER" in x.msg for x in found))

    def test_schema_cross_checks(self):
        src = (
            'pub const CSV_HEADER: &str = "step,comm_s";\n'
            'pub const CSV_SCHEMA: &[(&str, &str)] = &[("step", "step"), ("comm_s", "sim_comm_s")];\n'
            "pub struct StepRecord { pub step: usize, pub sim_comm_s: f64 }\n"
            "impl L { pub fn write_csv(&self) { for r in &self.records {\n"
            "    emit(r.step, r.sim_comm_s); } } }\n"
        )
        self.assertEqual(rules_units.check(sf(src, "rust/src/metrics/mod.rs")), [])
        # a swapped emission order must fire
        bad = src.replace("emit(r.step, r.sim_comm_s)", "emit(r.sim_comm_s, r.step)")
        found = rules_units.check(sf(bad, "rust/src/metrics/mod.rs"))
        self.assertTrue(any("write_csv emits" in x.msg for x in found))
        # a header/schema mismatch must fire
        bad = src.replace('"step,comm_s"', '"step,comm_s,extra"')
        found = rules_units.check(sf(bad, "rust/src/metrics/mod.rs"))
        self.assertTrue(any("do not match CSV_SCHEMA" in x.msg for x in found))
        # a column whose suffix disagrees with its field must fire
        bad = src.replace('("comm_s", "sim_comm_s")', '("comm", "sim_comm_s")').replace(
            '"step,comm_s"', '"step,comm"'
        )
        found = rules_units.check(sf(bad, "rust/src/metrics/mod.rs"))
        self.assertTrue(any("disagree on unit suffix" in x.msg for x in found))


class RatchetTest(unittest.TestCase):
    SRC = (
        "fn f(v: &[f64], m: &Mat) -> f64 {\n"
        "    #[derive(Clone)]\n"  # attribute bracket: not an index
        "    struct T;\n"
        "    let a = v[0] + v[1];\n"  # 2 index exprs
        "    let b = v.first().unwrap();\n"  # 1 unwrap
        "    let c = v.get(1).expect(\"one\");\n"  # 1 expect
        "    let d = vec![1, 2];\n"  # macro bracket: not an index
        "    a + b + c + d[0]\n"  # 1 index expr
        "}\n"
    )

    def test_count_panics(self):
        counts = rules_ratchet.count_panics(sf(self.SRC))
        self.assertEqual(counts, {"unwrap": 1, "expect": 1, "index": 3})

    def test_ratchet_only_goes_down(self):
        f = sf(self.SRC, "rust/src/comm/fixture.rs")
        with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as tmp:
            json.dump({f.relpath: {"unwrap": 1, "expect": 1, "index": 3}}, tmp)
            path = tmp.name
        try:
            self.assertEqual(rules_ratchet.check([f], path), [])
            with open(path, "w") as fh:  # tighten: the same counts now exceed
                json.dump({f.relpath: {"unwrap": 0, "expect": 1, "index": 3}}, fh)
            found = rules_ratchet.check([f], path)
            self.assertEqual(len(found), 1)
            self.assertIn("unwrap count 1 exceeds", found[0].msg)
        finally:
            os.unlink(path)

    def test_unlisted_file_with_panics_is_flagged(self):
        f = sf(self.SRC, "rust/src/comm/new_file.rs")
        with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as tmp:
            json.dump({}, tmp)
            path = tmp.name
        try:
            found = rules_ratchet.check([f], path)
            self.assertEqual(len(found), 1)
            self.assertIn("not in panic baseline", found[0].msg)
        finally:
            os.unlink(path)


class MirrorTest(unittest.TestCase):
    def test_fingerprint_ignores_formatting_but_not_tokens(self):
        a = sf("fn f(x: f64) -> f64 { x * 2.0 }\n")
        b = sf("fn f(\n    x: f64\n) -> f64 {\n    // doubled\n    x * 2.0\n}\n")
        c = sf("fn f(x: f64) -> f64 { x * 3.0 }\n")
        fa, fb, fc = (fn_fingerprint(s, "f") for s in (a, b, c))
        self.assertEqual(fa, fb, "whitespace/comment churn must not invalidate")
        self.assertNotEqual(fa, fc, "a token edit must invalidate")

    def test_edited_priced_fn_without_registry_update_fires(self):
        entries = rules_mirror.load_registry()
        target = next(e for e in entries if e["subsystem"] == "overlap-autotune")
        stale = [dict(target, fingerprint="0" * 64)]
        with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as tmp:
            json.dump({"entries": stale}, tmp)
            path = tmp.name
        try:
            findings, _ = run_lint(
                [os.path.join(REPO, "rust/src/overlap")],
                rules={"mirror"},
                repo_root=REPO,
                registry_path=path,
            )
            self.assertTrue(
                any("fingerprint changed" in x.msg for x in findings), findings
            )
        finally:
            os.unlink(path)

    def test_missing_mirror_symbol_fires(self):
        entries = rules_mirror.load_registry()
        target = dict(entries[0], mirror_symbol="no_such_symbol")
        with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as tmp:
            json.dump({"entries": [target]}, tmp)
            path = tmp.name
        try:
            findings, _ = run_lint(
                [os.path.join(REPO, "rust/src/comm")],
                rules={"mirror"},
                repo_root=REPO,
                registry_path=path,
            )
            self.assertTrue(any("no_such_symbol" in x.msg for x in findings))
            # dropping subsystems below the required set also fires
            self.assertTrue(
                any("required subsystems" in x.msg for x in findings), findings
            )
        finally:
            os.unlink(path)


class StructureTest(unittest.TestCase):
    def test_item_extraction(self):
        src = (
            "pub struct S { pub a_s: f64, b_bytes: usize }\n"
            "impl S { pub fn get(&self) -> f64 { self.a_s } fn hidden(&self) {} }\n"
        )
        f = sf(src)
        self.assertEqual([n for n, _ in struct_fields(f, "S")], ["a_s", "b_bytes"])
        names = {(n, p) for n, _, p in fn_names(f)}
        self.assertEqual(names, {("get", True), ("hidden", False)})

    def test_dead_pub_fn_crossref(self):
        f = sf("pub fn orphan_fn_zzz() {}\n", "rust/src/comm/fixture.rs")
        found = rules_structure.crossref([f], REPO)
        self.assertEqual(len(found), 1)
        self.assertIn("orphan_fn_zzz", found[0].msg)
        # a referenced fn passes: `main` is exempt, and anything that
        # appears twice in the corpus (definition + use) is fine
        f2 = sf("pub fn exchange_time() {}\n", "rust/src/comm/fixture.rs")
        self.assertEqual(rules_structure.crossref([f2], REPO), [])


class AcceptanceTest(unittest.TestCase):
    def test_real_crate_lints_clean(self):
        findings, files = run_lint(
            [os.path.join(REPO, "rust/src")], repo_root=REPO
        )
        self.assertEqual(
            [x.render() for x in findings], [], "rust/src must lint at zero findings"
        )
        self.assertGreaterEqual(len(files), 40)

    def test_tests_benches_examples_structure_clean(self):
        paths = [os.path.join(REPO, p) for p in ("rust/tests", "benches", "examples")]
        findings, _ = run_lint(paths, rules={"structure"}, repo_root=REPO)
        self.assertEqual([x.render() for x in findings], [])


if __name__ == "__main__":
    unittest.main(verbosity=2)
