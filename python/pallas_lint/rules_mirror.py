"""Rule family: mirror coverage.

The container that grows this repo has no rust toolchain, so every
numerical subsystem ships with a plain-python mirror of its decision
math (ROADMAP standing constraint; ``python/serve_mirror.py`` and
``python/mirrors/``). This rule makes that ritual enforceable:

``mirror_registry.json`` declares, per priced subsystem, which rust
function is the decision math, which python symbol mirrors it, and a
fingerprint of the rust function's token stream. The check fails when

* a registered rust function or python mirror symbol no longer exists,
* a registered rust function's tokens changed but the registry was not
  updated — i.e. a priced function changed without anyone re-validating
  its mirror (run ``python -m pallas_lint --update-fingerprints`` after
  updating the mirror), or
* the registry drops below the required subsystem set (comm pricing,
  BvN refinement, placement gate, overlap autotune, serve cache,
  serve batcher).
"""

from __future__ import annotations

import ast
import json
import os
from typing import Dict, List

from . import config
from .findings import Finding
from .items import SourceFile, fn_fingerprint

REGISTRY_FILE = os.path.join(os.path.dirname(__file__), "mirror_registry.json")


def load_registry(path: str = REGISTRY_FILE) -> List[Dict[str, str]]:
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)["entries"]


def save_registry(entries: List[Dict[str, str]], path: str = REGISTRY_FILE) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"entries": entries}, f, indent=2, sort_keys=False)
        f.write("\n")


def _python_symbols(py_path: str) -> set:
    """Top-level functions/classes and `Class.method` names of a file."""
    with open(py_path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=py_path)
    syms = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            syms.add(node.name)
        elif isinstance(node, ast.ClassDef):
            syms.add(node.name)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    syms.add(f"{node.name}.{sub.name}")
    return syms


def check(repo_root: str, update_fingerprints: bool = False) -> List[Finding]:
    out: List[Finding] = []
    try:
        entries = load_registry(REGISTRY_FILE)
    except (OSError, ValueError, KeyError) as e:
        return [Finding("python/pallas_lint/mirror_registry.json", 1, "mirror", f"unreadable registry: {e}")]

    subsystems = {e.get("subsystem", "") for e in entries}
    missing = config.REQUIRED_SUBSYSTEMS - subsystems
    if missing:
        out.append(
            Finding(
                "python/pallas_lint/mirror_registry.json",
                1,
                "mirror",
                f"registry no longer covers required subsystems: {sorted(missing)}",
            )
        )

    dirty = False
    for e in entries:
        where = f"{e['subsystem']}: {e['rust_file']}::{e['rust_fn']}"
        rust_path = os.path.join(repo_root, e["rust_file"])
        if not os.path.isfile(rust_path):
            out.append(Finding(e["rust_file"], 1, "mirror", f"{where}: rust file missing"))
            continue
        with open(rust_path, "r", encoding="utf-8") as f:
            sf = SourceFile(e["rust_file"], f.read())
        fp = fn_fingerprint(sf, e["rust_fn"])
        if fp is None:
            out.append(
                Finding(
                    e["rust_file"],
                    1,
                    "mirror",
                    f"{where}: registered fn not found — priced decision "
                    "math moved without updating the mirror registry",
                )
            )
            continue
        if update_fingerprints:
            if e.get("fingerprint") != fp:
                e["fingerprint"] = fp
                dirty = True
        elif e.get("fingerprint") != fp:
            out.append(
                Finding(
                    e["rust_file"],
                    1,
                    "mirror",
                    f"{where}: fingerprint changed — the priced function "
                    f"was edited; re-validate `{e['mirror_file']}::"
                    f"{e['mirror_symbol']}` against it, then run "
                    "`python -m pallas_lint --update-fingerprints`",
                )
            )

        py_path = os.path.join(repo_root, e["mirror_file"])
        if not os.path.isfile(py_path):
            out.append(Finding(e["mirror_file"], 1, "mirror", f"{where}: mirror file missing"))
            continue
        try:
            syms = _python_symbols(py_path)
        except SyntaxError as ex:
            out.append(Finding(e["mirror_file"], ex.lineno or 1, "mirror", f"mirror does not parse: {ex.msg}"))
            continue
        if e["mirror_symbol"] not in syms:
            out.append(
                Finding(
                    e["mirror_file"],
                    1,
                    "mirror",
                    f"{where}: mirror symbol `{e['mirror_symbol']}` missing",
                )
            )
    if update_fingerprints and dirty:
        save_registry(entries)
    return out
