"""Scan orchestration: discover files, run rule families, collect findings."""

from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from . import (
    rules_determinism,
    rules_mirror,
    rules_ratchet,
    rules_structure,
    rules_units,
)
from .findings import Finding
from .items import SourceFile

ALL_RULES = {"determinism", "units", "mirror", "ratchet", "structure"}


def find_repo_root(start: str) -> str:
    cur = os.path.abspath(start)
    if os.path.isfile(cur):
        cur = os.path.dirname(cur)
    while True:
        if os.path.isfile(os.path.join(cur, "Cargo.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            raise SystemExit(f"pallas-lint: no Cargo.toml above {start}")
        cur = parent


def discover(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".rs"):
            out.append(os.path.abspath(p))
        elif os.path.isdir(p):
            for dirpath, _dirs, names in os.walk(p):
                for name in sorted(names):
                    if name.endswith(".rs"):
                        out.append(os.path.abspath(os.path.join(dirpath, name)))
        else:
            raise SystemExit(f"pallas-lint: no such path {p}")
    return sorted(set(out))


def load_files(abs_paths: Iterable[str], repo_root: str) -> List[SourceFile]:
    files: List[SourceFile] = []
    for ap in abs_paths:
        rel = os.path.relpath(ap, repo_root).replace(os.sep, "/")
        with open(ap, "r", encoding="utf-8") as f:
            files.append(SourceFile(rel, f.read()))
    return files


def run_lint(
    paths: Sequence[str],
    rules: Optional[Set[str]] = None,
    repo_root: Optional[str] = None,
    update_fingerprints: bool = False,
    baseline_path: Optional[str] = None,
    registry_path: Optional[str] = None,
) -> Tuple[List[Finding], List[SourceFile]]:
    rules = set(rules) if rules else set(ALL_RULES)
    unknown = rules - ALL_RULES
    if unknown:
        raise SystemExit(f"pallas-lint: unknown rule families {sorted(unknown)}")
    root = repo_root or find_repo_root(paths[0] if paths else ".")
    files = load_files(discover(paths), root)

    findings: List[Finding] = []
    for sf in files:
        # malformed/unjustified allow directives are findings regardless
        # of which families run — an allowlist is policy, not a loophole
        findings.extend(sf.directive_findings)
        if "structure" in rules:
            findings.extend(rules_structure.check_file(sf))
        if "determinism" in rules:
            findings.extend(rules_determinism.check(sf))
        if "units" in rules:
            findings.extend(rules_units.check(sf))
    if "structure" in rules:
        findings.extend(rules_structure.crossref(files, root))
    if "ratchet" in rules:
        findings.extend(
            rules_ratchet.check(files, baseline_path or rules_ratchet.BASELINE_FILE)
        )
    if "mirror" in rules:
        findings.extend(
            _run_mirror(root, update_fingerprints, registry_path)
        )
    return sorted(set(findings)), files


def _run_mirror(root: str, update: bool, registry_path: Optional[str]) -> List[Finding]:
    if registry_path is None:
        return rules_mirror.check(root, update)
    # test seam: point the rule at an alternate registry
    orig = rules_mirror.REGISTRY_FILE
    rules_mirror.REGISTRY_FILE = registry_path
    try:
        return rules_mirror.check(root, update)
    finally:
        rules_mirror.REGISTRY_FILE = orig
