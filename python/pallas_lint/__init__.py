"""pallas-lint: toolchain-free static analysis for the ta_moe crate.

A stdlib-only python analyzer with a lightweight rust tokenizer and five
rule families (DESIGN.md §static-analysis):

* ``determinism``  — no unordered collections, wall clocks, or ambient
  RNG in priced/decision modules.
* ``units``        — ``_s``/``_bytes``/``_gbps`` suffix consistency
  across struct fields, the CSV schema in ``metrics/mod.rs``, and
  summary-JSON keys.
* ``mirror``       — the declared registry of decision-math functions
  must have python mirror counterparts, and a registered rust function
  cannot change without the registry (and mirror) being touched.
* ``ratchet``      — per-file ``unwrap``/``expect``/indexing budgets
  pinned in a checked-in baseline that may only decrease.
* ``structure``    — delimiter balance and pub-fn call-site
  cross-reference, automating what PRs 1–6 verified by hand.

Run ``python -m pallas_lint rust/src`` from the repo root. Exit code 0
means zero findings. The container needs no cargo/rustc.
"""

from .findings import Finding
from .runner import run_lint

__version__ = "0.1.0"
__all__ = ["Finding", "run_lint", "__version__"]
