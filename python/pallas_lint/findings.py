"""Finding type shared by every rule family."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True, order=True)
class Finding:
    path: str  # repo-relative, '/'-separated
    line: int
    rule: str  # determinism | units | mirror | ratchet | structure | allowlist
    msg: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"
