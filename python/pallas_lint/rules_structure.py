"""Rule family: structure.

Automates the two checks every previous PR ran by hand in lieu of a
compiler (CHANGES.md, PRs 1–6):

* **delimiter balance** — parens/brackets/braces must balance per file,
  with strings, char literals, lifetimes, and (nested) comments lexed
  properly so they can't fool the count. Catches the gross syntax
  slips a missing toolchain would otherwise let through.
* **call-site cross-reference** — every plain ``pub fn`` in the scanned
  tree must be referenced somewhere else in the repo's rust corpus
  (``rust/``, ``benches/``, ``examples/``): a public function nobody
  calls or tests is either dead API or a wiring mistake (the
  cross-reference PRs 1–6 performed manually after each refactor).
  ``main`` and trait-required methods referenced via their trait
  declaration pass naturally (the trait's ``fn`` name counts as a
  reference).
"""

from __future__ import annotations

import os
from typing import Dict, List

from .findings import Finding
from .items import SourceFile, delimiter_findings, fn_names
from .rustlex import lex

CROSSREF_EXEMPT = {"main"}


def check_file(sf: SourceFile) -> List[Finding]:
    return delimiter_findings(sf)


def _ident_counts(path: str) -> Dict[str, int]:
    with open(path, "r", encoding="utf-8") as f:
        toks, _, _ = lex(f.read())
    counts: Dict[str, int] = {}
    for t in toks:
        if t.kind == "ident":
            counts[t.text] = counts.get(t.text, 0) + 1
    return counts


def crossref(files: List[SourceFile], repo_root: str) -> List[Finding]:
    """Flag pub fns whose name appears nowhere beyond its definition."""
    # reference corpus: every .rs file under the repo's rust trees
    corpus: Dict[str, int] = {}
    for sub in ("rust", "benches", "examples"):
        base = os.path.join(repo_root, sub)
        for dirpath, _dirs, names in os.walk(base):
            for name in sorted(names):
                if name.endswith(".rs"):
                    for ident, c in _ident_counts(os.path.join(dirpath, name)).items():
                        corpus[ident] = corpus.get(ident, 0) + c

    out: List[Finding] = []
    for sf in files:
        for name, line, is_pub in fn_names(sf):
            if not is_pub or name in CROSSREF_EXEMPT:
                continue
            if sf.allowed(line, "structure"):
                continue
            # the definition itself contributes exactly one occurrence;
            # anything beyond it (call, trait decl, re-export, test) is
            # a reference
            if corpus.get(name, 0) < 2:
                out.append(
                    Finding(
                        sf.relpath,
                        line,
                        "structure",
                        f"pub fn `{name}` has no call sites or references "
                        "anywhere in rust/, benches/, or examples/ — dead "
                        "API or missed wiring",
                    )
                )
    return out
