"""Rule family: panic-path ratchet.

``unwrap``/``expect``/direct indexing are panic paths: fine where an
invariant genuinely holds, corrosive when they accrete. Instead of
litigating each one, the linter pins today's per-file counts in
``panic_baseline.json`` and enforces a ratchet: a file's count may
only stay or go down. New files start at budget 0 unless the baseline
is regenerated (``--write-baseline``) in the same PR that adds them —
which shows up in review as a diff to the checked-in baseline.

Counted mechanically on the token stream:

* ``unwrap`` / ``expect`` call tokens (any receiver),
* index expressions — a ``[`` directly following an identifier, ``)``
  or ``]`` (attribute ``#[..]`` and macro ``vec![..]`` forms excluded).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from .findings import Finding
from .items import SourceFile

BASELINE_FILE = os.path.join(os.path.dirname(__file__), "panic_baseline.json")

COUNTERS = ("unwrap", "expect", "index")


def count_panics(sf: SourceFile) -> Dict[str, int]:
    toks = sf.toks
    counts = {c: 0 for c in COUNTERS}
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text in ("unwrap", "expect"):
            if i + 1 < len(toks) and toks[i + 1].text == "(":
                counts[t.text] += 1
        elif t.kind == "punct" and t.text == "[" and i >= 1:
            prev = toks[i - 1]
            if prev.kind == "ident" or prev.text in (")", "]"):
                if i >= 2 and toks[i - 2].text == "#":
                    continue  # attribute #[...]
                counts["index"] += 1
    return counts


def load_baseline(path: str = BASELINE_FILE) -> Dict[str, Dict[str, int]]:
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def write_baseline(files: List[SourceFile], path: str = BASELINE_FILE) -> None:
    data = {sf.relpath: count_panics(sf) for sf in sorted(files, key=lambda s: s.relpath)}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def check(files: List[SourceFile], baseline_path: str = BASELINE_FILE) -> List[Finding]:
    out: List[Finding] = []
    try:
        baseline = load_baseline(baseline_path)
    except (OSError, ValueError) as e:
        return [
            Finding(
                "python/pallas_lint/panic_baseline.json",
                1,
                "ratchet",
                f"unreadable panic baseline: {e} (regenerate with --write-baseline)",
            )
        ]
    for sf in files:
        counts = count_panics(sf)
        budget = baseline.get(sf.relpath)
        if budget is None:
            if any(counts.values()):
                out.append(
                    Finding(
                        sf.relpath,
                        1,
                        "ratchet",
                        f"file not in panic baseline but has panic paths "
                        f"{counts}; add it via --write-baseline (reviewed "
                        "as a baseline diff)",
                    )
                )
            continue
        for c in COUNTERS:
            if counts[c] > budget.get(c, 0):
                out.append(
                    Finding(
                        sf.relpath,
                        1,
                        "ratchet",
                        f"panic-path ratchet: {c} count {counts[c]} exceeds "
                        f"the pinned budget {budget.get(c, 0)} — handle the "
                        "error or tighten the invariant instead",
                    )
                )
    return out
