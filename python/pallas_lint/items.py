"""Item extraction over the token stream: files, fns, structs, allows."""

from __future__ import annotations

import hashlib
import re
from typing import Dict, List, Optional, Set, Tuple

from . import config
from .findings import Finding
from .rustlex import Comment, LexError, Tok, lex

DIRECTIVE_RE = re.compile(
    r"pallas-lint:\s*allow\(([a-z\-, ]+)\)\s*(?:--|—|:)?\s*(.*)"
)

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {")": "(", "]": "[", "}": "{"}


class SourceFile:
    """One lexed rust file plus its allow directives."""

    def __init__(self, relpath: str, src: str):
        self.relpath = relpath
        self.src = src
        self.toks, self.comments, self.errors = lex(src)
        # line -> set of rules allowed there (directive line + next line)
        self.allows: Dict[int, Set[str]] = {}
        self.directive_findings: List[Finding] = []
        self._parse_directives()

    def _parse_directives(self) -> None:
        for com in self.comments:
            if config.DIRECTIVE_MARKER not in com.text:
                continue
            m = DIRECTIVE_RE.search(com.text)
            if not m:
                self.directive_findings.append(
                    Finding(
                        self.relpath,
                        com.line,
                        "allowlist",
                        "malformed pallas-lint directive (expected "
                        "`pallas-lint: allow(<rule>) -- <justification>`)",
                    )
                )
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            justification = m.group(2).strip()
            # multi-line justifications continue on following comment lines;
            # accept them by looking at the raw comment only — require the
            # directive line itself to carry the why
            if len(justification) < config.MIN_JUSTIFICATION:
                self.directive_findings.append(
                    Finding(
                        self.relpath,
                        com.line,
                        "allowlist",
                        "pallas-lint allow directive without an inline "
                        "justification (policy: every exception says why)",
                    )
                )
                continue
            last = com.line + com.text.count("\n")
            for line in (com.line, last + 1):
                self.allows.setdefault(line, set()).update(rules)

    def allowed(self, line: int, rule: str) -> bool:
        return rule in self.allows.get(line, ())


def struct_fields(sf: SourceFile, struct_name: str) -> List[Tuple[str, int]]:
    """Field names of `struct struct_name { .. }` in declaration order."""
    toks = sf.toks
    out: List[Tuple[str, int]] = []
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "struct":
            if i + 1 < len(toks) and toks[i + 1].text == struct_name:
                # find the opening brace (skip generics)
                j = i + 2
                while j < len(toks) and toks[j].text != "{":
                    j += 1
                depth = 0
                expect_field = True
                while j < len(toks):
                    t2 = toks[j]
                    if t2.text == "{":
                        depth += 1
                        if depth == 1:
                            expect_field = True
                    elif t2.text == "}":
                        depth -= 1
                        if depth == 0:
                            return out
                    elif depth == 1:
                        if t2.text == ",":
                            expect_field = True
                        elif (
                            expect_field
                            and t2.kind == "ident"
                            and t2.text != "pub"
                            and j + 1 < len(toks)
                            and toks[j + 1].text == ":"
                            and (j + 2 >= len(toks) or toks[j + 2].text != ":")
                        ):
                            out.append((t2.text, t2.line))
                            expect_field = False
                    j += 1
                return out
    return out


def all_struct_fields(sf: SourceFile) -> List[Tuple[str, int]]:
    """(field, line) for every struct with named fields in the file."""
    toks = sf.toks
    out: List[Tuple[str, int]] = []
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "struct" and i + 1 < len(toks):
            name = toks[i + 1]
            if name.kind == "ident":
                out.extend(struct_fields(sf, name.text))
    # struct_fields re-scans from the top, so de-dup by (name, line)
    return sorted(set(out), key=lambda x: x[1])


def fn_names(sf: SourceFile) -> List[Tuple[str, int, bool]]:
    """(name, line, is_pub) for every `fn` item/method in the file.

    `is_pub` is true only for plain `pub fn` (not `pub(crate)`), i.e.
    the crate's public API surface.
    """
    toks = sf.toks
    out: List[Tuple[str, int, bool]] = []
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "fn" and i + 1 < len(toks):
            name = toks[i + 1]
            if name.kind != "ident":
                continue
            is_pub = i >= 1 and toks[i - 1].kind == "ident" and toks[i - 1].text == "pub"
            out.append((name.text, name.line, is_pub))
    return out


def fn_token_span(sf: SourceFile, fn_name: str) -> Optional[Tuple[int, int]]:
    """[start, end] token indices of `fn fn_name .. { .. }` (first match).

    Starts at the `fn` keyword and ends at the matching close brace of
    the body, so a signature or body edit always changes the span.
    """
    toks = sf.toks
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "fn":
            if i + 1 < len(toks) and toks[i + 1].kind == "ident" and toks[i + 1].text == fn_name:
                depth = 0
                seen_body = False
                j = i
                while j < len(toks):
                    txt = toks[j].text
                    if txt == "{":
                        depth += 1
                        seen_body = True
                    elif txt == "}":
                        depth -= 1
                        if seen_body and depth == 0:
                            return (i, j)
                    elif txt == ";" and not seen_body and depth == 0:
                        return (i, j)  # bodyless (trait) fn
                    j += 1
                return (i, len(toks) - 1)
    return None


def fn_fingerprint(sf: SourceFile, fn_name: str) -> Optional[str]:
    """sha256 over the fn's normalized token stream (whitespace- and
    comment-insensitive, so formatting churn never invalidates it)."""
    span = fn_token_span(sf, fn_name)
    if span is None:
        return None
    i, j = span
    blob = "\x1f".join(f"{t.kind}:{t.text}" for t in sf.toks[i : j + 1])
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def delimiter_findings(sf: SourceFile) -> List[Finding]:
    """Balance check over the token stream (strings/comments excluded)."""
    out = [
        Finding(sf.relpath, e.line, "structure", e.msg) for e in sf.errors
    ]
    stack: List[Tok] = []
    for t in sf.toks:
        if t.kind != "punct":
            continue
        if t.text in OPEN:
            stack.append(t)
        elif t.text in CLOSE:
            if not stack or stack[-1].text != CLOSE[t.text]:
                opener = stack[-1] if stack else None
                ctx = (
                    f" (innermost open `{opener.text}` at line {opener.line})"
                    if opener
                    else ""
                )
                out.append(
                    Finding(
                        sf.relpath,
                        t.line,
                        "structure",
                        f"unbalanced `{t.text}`{ctx}",
                    )
                )
                return out  # everything after is noise
            stack.pop()
    if stack:
        t = stack[-1]
        out.append(
            Finding(sf.relpath, t.line, "structure", f"unclosed `{t.text}`")
        )
    return out
