"""Rule family: determinism.

Every pricing and routing decision in this repo is seeded and
bit-exact (ROADMAP standing constraint); the benchmarks are only
comparable because two runs of the same config produce the same
numbers. Three mechanical hazards break that silently:

* ``HashMap``/``HashSet`` — iteration order differs per *instance*
  (each map draws its own ``RandomState``), so any fold, emission, or
  first-wins assignment over one is nondeterministic. Forbidden in
  every scanned file; ``BTreeMap``/``BTreeSet`` are the replacements.
* ``Instant``/``SystemTime`` — wall clocks inside priced modules leak
  host timing into decisions. Forbidden in ``PRICED_DIRS``.
* ``thread_rng``/``from_entropy``/``OsRng`` — ambient RNG is unseeded
  by construction. Forbidden in ``PRICED_DIRS`` (the repo's own
  ``util::rng::Rng`` is the seeded alternative).

Exceptions carry an inline ``pallas-lint: allow(determinism) -- why``
directive; the one sanctioned pattern is observability-only wall-clock
measurement that never feeds the simulated clock.
"""

from __future__ import annotations

from typing import List

from . import config
from .findings import Finding
from .items import SourceFile


def _in_priced_dir(relpath: str) -> bool:
    parts = relpath.replace("\\", "/").split("/")
    if "src" in parts:
        parts = parts[parts.index("src") + 1 :]
    return bool(parts) and parts[0] in config.PRICED_DIRS


def check(sf: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    priced = _in_priced_dir(sf.relpath)
    for t in sf.toks:
        if t.kind != "ident":
            continue
        if t.text in config.UNORDERED_TYPES:
            if sf.allowed(t.line, "determinism"):
                continue
            out.append(
                Finding(
                    sf.relpath,
                    t.line,
                    "determinism",
                    f"`{t.text}` iterates in per-instance random order; "
                    "use BTreeMap/BTreeSet or sort before iterating "
                    "(allow only for documented naive oracles)",
                )
            )
        elif priced and t.text in config.WALL_CLOCKS:
            if sf.allowed(t.line, "determinism"):
                continue
            out.append(
                Finding(
                    sf.relpath,
                    t.line,
                    "determinism",
                    f"wall clock `{t.text}` in priced module; simulated "
                    "time must come from the cost engine, not the host",
                )
            )
        elif priced and t.text in config.AMBIENT_RNG:
            if sf.allowed(t.line, "determinism"):
                continue
            out.append(
                Finding(
                    sf.relpath,
                    t.line,
                    "determinism",
                    f"ambient RNG `{t.text}` in priced module; draw from "
                    "the seeded util::rng::Rng instead",
                )
            )
    return out
