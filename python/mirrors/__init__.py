"""Plain-python mirrors of the crate's priced decision math.

The container that grows this repo has no rust toolchain (ROADMAP
standing constraint), so every numerical subsystem ships a python
mirror of its decision rules, validated by self-checks that run in CI
and in this container. ``python/serve_mirror.py`` covers the serving
stack (rng, traces, cache, batcher); this package covers the rest:

* :mod:`mirrors.comm_pricing`     — α-β link pricing with contention and
  the self-copy overlap convention (``rust/src/comm/engine.rs``);
* :mod:`mirrors.bvn_refine`       — heaviest-first peeling and the
  Kempe-style alternating-component refinement of round schedules
  (``rust/src/comm/plan.rs``);
* :mod:`mirrors.placement_gate`   — EWMA gate-load tracking and the
  amortised migration accept/reject gate
  (``rust/src/placement/engine.rs``);
* :mod:`mirrors.overlap_autotune` — the chunk-count sweep and its
  near-tie selection rule (``rust/src/overlap/autotune.rs``);
* :mod:`mirrors.perturb_recovery` — straggler windowing and the
  recovery-step detector (``rust/src/perturb/mod.rs``);
* :mod:`mirrors.trace_utilization` — the per-resource utilization
  report fold: busy fractions, straggler skew, hottest-k
  (``rust/src/trace/report.rs``).

``python/pallas_lint/mirror_registry.json`` pins each mirror symbol to
the rust function it mirrors by token fingerprint: editing the priced
rust function without re-validating its mirror fails the lint.

Run any module directly (``python3 -m mirrors.comm_pricing``) for its
self-check; each exits nonzero on the first violated invariant.
"""

from __future__ import annotations

__all__ = [
    "comm_pricing",
    "bvn_refine",
    "placement_gate",
    "overlap_autotune",
    "perturb_recovery",
    "trace_utilization",
]
