#!/usr/bin/env python3
"""Mirror of the BvN round-schedule synthesis math (rust/src/comm/plan.rs).

Ports the decision rules of the byte-matrix-aware schedule synthesiser:

* ``peel_rounds`` — greedy heaviest-first maximal partial permutations,
  with the exact tie-break (descending weight, then ascending
  ``(src, dst)``) that makes peeling deterministic;
* ``alternating_components`` — the component decomposition of two
  partial permutations whose flips keep both rounds valid;
* ``round_cost`` — max contended delivery time of a round under a live
  link census, with the early-exit bound;
* ``refine_rounds`` — the Kempe-style local search: flip components
  between the most expensive round and cheaper ones, accepting iff
  ``c_na + c_nb < budget * (1 - 1e-12)``, at most ``REFINE_SWEEPS``
  sweeps. Monotone non-increasing by construction.

Pricing runs through :mod:`mirrors.comm_pricing` (the engine mirror).
Run ``python3 -m mirrors.bvn_refine`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import List, Sequence, Tuple

from mirrors.comm_pricing import (
    Topology,
    census_add,
    census_sub,
    contended_time,
    two_node_tree,
)

Pair = Tuple[int, int]
Round = List[Pair]

REFINE_SWEEPS = 12  # plan.rs: bounded flips per candidate schedule


def peel_rounds(pairs: List[Tuple[int, int, float]], p: int) -> List[Round]:
    """Greedily peel (src, dst, weight) into maximal partial permutations,
    heaviest first; ties broken by ascending (src, dst)."""
    pairs = sorted(pairs, key=lambda e: (-e[2], e[0], e[1]))
    rounds: List[Round] = []
    while pairs:
        send = [False] * p
        recv = [False] * p
        rnd: Round = []
        rest: List[Tuple[int, int, float]] = []
        for i, j, w in pairs:
            if not send[i] and not recv[j]:
                send[i] = True
                recv[j] = True
                rnd.append((i, j))
            else:
                rest.append((i, j, w))
        rounds.append(rnd)
        pairs = rest
    return rounds


def alternating_components(a: Round, b: Round, p: int) -> List[Tuple[Round, Round]]:
    """Alternating components of two partial permutations.

    Each component is ``(from_a, from_b)``; swapping a component's
    deliveries between the rounds keeps every device at ≤1 send and ≤1
    receive per round, and flips of distinct components compose.
    """
    NONE = -1
    out_a = [NONE] * p
    in_a = [NONE] * p
    for k, (i, j) in enumerate(a):
        out_a[i] = k
        in_a[j] = k
    out_b = [NONE] * p
    in_b = [NONE] * p
    for k, (i, j) in enumerate(b):
        out_b[i] = k
        in_b[j] = k
    seen_a = [False] * len(a)
    seen_b = [False] * len(b)
    comps: List[Tuple[Round, Round]] = []
    starts = [(True, k) for k in range(len(a))] + [(False, k) for k in range(len(b))]
    for start in starts:
        is_a0, k0 = start
        if (is_a0 and seen_a[k0]) or (not is_a0 and seen_b[k0]):
            continue
        ca: Round = []
        cb: Round = []
        stack = [start]
        while stack:
            is_a, k = stack.pop()
            if is_a:
                if seen_a[k]:
                    continue
                seen_a[k] = True
                i, j = a[k]
                ca.append((i, j))
                if out_b[i] != NONE:
                    stack.append((False, out_b[i]))
                if in_b[j] != NONE:
                    stack.append((False, in_b[j]))
            else:
                if seen_b[k]:
                    continue
                seen_b[k] = True
                i, j = b[k]
                cb.append((i, j))
                if out_a[i] != NONE:
                    stack.append((True, out_a[i]))
                if in_a[j] != NONE:
                    stack.append((True, in_a[j]))
        comps.append((ca, cb))
    return comps


def round_cost(
    topo: Topology,
    bytes_mat: Sequence[Sequence[float]],
    census: Sequence[int],
    pairs,
    bound: float,
) -> float:
    """Max contended delivery time of ``pairs``, early-exiting at
    ``bound`` (enough to reject a flip against the combined budget)."""
    t = 0.0
    for i, j in pairs:
        if i == j:
            continue
        b = bytes_mat[i][j]
        if b <= 0.0:
            continue
        t = max(t, contended_time(topo, census, i, j, b))
        if t >= bound:
            return t
    return t


def refine_rounds(
    topo: Topology, bytes_mat: Sequence[Sequence[float]], rounds: List[Round]
) -> List[Round]:
    """Kempe-style local search over round schedules (plan.rs).

    Flip alternating components between the most expensive round and a
    cheaper one whenever the priced cost drops:
    accept iff ``c_na + c_nb < budget * (1 - 1e-12)``. Monotone
    non-increasing, so a rotation seed never gets worse.
    """
    p = topo.p
    rounds = [r for r in rounds if any(i != j for i, j in r)]
    n_slots = topo.n_slots()
    live = lambda i, j: i != j and bytes_mat[i][j] > 0.0

    states = []
    for pairs in rounds:
        census = [0] * n_slots
        for i, j in pairs:
            if live(i, j):
                census_add(topo, census, i, j)
        cost = round_cost(topo, bytes_mat, census, list(pairs), float("inf"))
        states.append({"pairs": list(pairs), "census": census, "cost": cost})

    for _ in range(REFINE_SWEEPS):
        if not states:
            break
        a = max(range(len(states)), key=lambda k: states[k]["cost"])
        if states[a]["cost"] <= 0.0:
            break
        order = sorted(
            (k for k in range(len(states)) if k != a), key=lambda k: states[k]["cost"]
        )
        improved = False
        for b in order:
            sa, sb = states[a], states[b]
            comps = alternating_components(sa["pairs"], sb["pairs"], p)
            for ca, cb in comps:
                if not ca and not cb:
                    continue
                budget = sa["cost"] + sb["cost"]
                for i, j in ca:
                    if live(i, j):
                        census_sub(topo, sa["census"], i, j)
                        census_add(topo, sb["census"], i, j)
                for i, j in cb:
                    if live(i, j):
                        census_sub(topo, sb["census"], i, j)
                        census_add(topo, sa["census"], i, j)
                c_na = round_cost(
                    topo,
                    bytes_mat,
                    sa["census"],
                    [pr for pr in sa["pairs"] if pr not in ca] + list(cb),
                    budget,
                )
                c_nb = (
                    round_cost(
                        topo,
                        bytes_mat,
                        sb["census"],
                        [pr for pr in sb["pairs"] if pr not in cb] + list(ca),
                        budget - c_na,
                    )
                    if c_na < budget
                    else float("inf")
                )
                if c_na + c_nb < budget * (1.0 - 1e-12):
                    sa["pairs"] = [pr for pr in sa["pairs"] if pr not in ca] + list(cb)
                    sb["pairs"] = [pr for pr in sb["pairs"] if pr not in cb] + list(ca)
                    sa["cost"] = c_na
                    sb["cost"] = c_nb
                    improved = True
                else:
                    for i, j in ca:
                        if live(i, j):
                            census_add(topo, sa["census"], i, j)
                            census_sub(topo, sb["census"], i, j)
                    for i, j in cb:
                        if live(i, j):
                            census_add(topo, sb["census"], i, j)
                            census_sub(topo, sa["census"], i, j)
            if improved:
                break
        if not improved:
            break
    return [s["pairs"] for s in states if s["pairs"]]


# ----------------------------------------------------------- self-check


def _is_partial_permutation(rnd: Round, p: int) -> bool:
    return (
        len({i for i, _ in rnd}) == len(rnd) and len({j for _, j in rnd}) == len(rnd)
    )


def _max_round_cost(topo, bytes_mat, rounds) -> float:
    worst = 0.0
    for rnd in rounds:
        census = [0] * topo.n_slots()
        for i, j in rnd:
            if i != j and bytes_mat[i][j] > 0.0:
                census_add(topo, census, i, j)
        worst = max(worst, round_cost(topo, bytes_mat, census, rnd, float("inf")))
    return worst


def main() -> int:
    p = 4
    t = two_node_tree()

    # -- peeling: heaviest first, deterministic tie-break --------------
    pairs = [(0, 1, 3.0), (1, 0, 3.0), (0, 2, 5.0), (2, 3, 1.0), (1, 2, 5.0)]
    rounds = peel_rounds(list(pairs), p)
    assert rounds[0][0] == (0, 2), rounds  # weight 5, (0,2) < (1,2)
    for rnd in rounds:
        assert _is_partial_permutation(rnd, p), rnd
    assert sorted((i, j) for r in rounds for (i, j) in r) == sorted(
        (i, j) for i, j, _ in pairs
    )

    # -- components partition and preserve validity --------------------
    a = [(0, 1), (1, 2), (2, 3)]
    b = [(0, 2), (1, 3), (2, 1)]
    comps = alternating_components(a, b, p)
    assert sorted(pr for ca, _ in comps for pr in ca) == sorted(a)
    assert sorted(pr for _, cb in comps for pr in cb) == sorted(b)
    for ca, cb in comps:  # each flip keeps both rounds valid
        na = [pr for pr in a if pr not in ca] + cb
        nb = [pr for pr in b if pr not in cb] + ca
        assert _is_partial_permutation(na, p) and _is_partial_permutation(nb, p)

    # -- refinement: monotone, permutation-preserving ------------------
    mb = 1e6
    bytes_mat = [[0.0] * p for _ in range(p)]
    # a heavy and a light cross-node delivery crowd the uplink in one
    # round: the census doubles the heavy delivery's β, so moving the
    # light one out is a strict improvement (with equal weights the split
    # is cost-neutral under the flow census and correctly rejected)
    bytes_mat[0][2] = 4 * mb
    bytes_mat[1][3] = mb
    bytes_mat[0][1] = mb
    bytes_mat[2][3] = mb
    seed = [[(0, 2), (1, 3)], [(0, 1), (2, 3)]]
    before = _max_round_cost(t, bytes_mat, seed)
    refined = refine_rounds(t, bytes_mat, [list(r) for r in seed])
    after = _max_round_cost(t, bytes_mat, refined)
    assert after < before, (before, after)
    sent = sorted(pr for r in refined for pr in r)
    assert sent == sorted(pr for r in seed for pr in r), "deliveries conserved"
    for rnd in refined:
        assert _is_partial_permutation(rnd, p), rnd

    # the two heavy cross-node deliveries share the uplink census: the
    # refiner must split them into different rounds
    heavy_rounds = [
        k for k, rnd in enumerate(refined) if (0, 2) in rnd or (1, 3) in rnd
    ]
    assert len(heavy_rounds) == 2 and heavy_rounds[0] != heavy_rounds[1], refined

    # -- empty/self-only rounds are dropped ----------------------------
    assert refine_rounds(t, bytes_mat, [[(0, 0), (1, 1)]]) == []

    print("mirrors.bvn_refine: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
