#!/usr/bin/env python3
"""Mirror of the placement engine's decision math
(rust/src/placement/engine.rs, rust/src/placement/mod.rs).

Three rules decide whether a live expert migration happens:

* ``cadence_due`` — the engine only considers a move every
  ``cfg.every`` steps (never at step 0, never when disabled);
* ``GateLoadEwma`` — the load estimate the solver sees: the first
  observation seeds the estimate directly (no decay toward the zero
  init), then ``l = (1 - a)·l + a·c`` per step;
* ``migration_gate`` — the amortisation accept/reject: a candidate
  placement is applied iff its predicted per-step saving is positive
  AND pays for the migration within the horizon:
  reject iff ``saving_s <= 0 or saving_s * horizon < cost_s``.

Run ``python3 -m mirrors.placement_gate`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import List, Sequence


def cadence_due(steps: int, every: int) -> bool:
    """Whether `maybe_replace` even considers a candidate at this step."""
    return every != 0 and steps != 0 and steps % every == 0


class GateLoadEwma:
    """EWMA over per-step dispatch counts (placement/mod.rs).

    ``alpha`` is the weight of the newest observation (0 < alpha ≤ 1);
    the first observation seeds the estimate directly.
    """

    def __init__(self, p: int, n_experts: int, alpha: float):
        assert 0.0 < alpha <= 1.0, f"ewma alpha {alpha} out of (0, 1]"
        self.loads: List[List[float]] = [[0.0] * n_experts for _ in range(p)]
        self.alpha = alpha
        self.steps = 0

    def observe(self, counts: Sequence[Sequence[float]]) -> None:
        assert len(counts) == len(self.loads)
        assert all(len(r) == len(self.loads[0]) for r in counts)
        if self.steps == 0:
            self.loads = [list(row) for row in counts]
        else:
            a = self.alpha
            for li, ci in zip(self.loads, counts):
                for e in range(len(li)):
                    li[e] = (1.0 - a) * li[e] + a * ci[e]
        self.steps += 1


def migration_gate(predicted_saving_s: float, horizon: float, cost_s: float) -> bool:
    """The amortisation gate of `maybe_replace` (engine.rs).

    True = migrate. The candidate must save time at all, and the saving
    over ``horizon`` steps must cover the one-off migration cost — both
    priced under the clock the session actually runs (a2a plan or
    overlapped makespan), never the solver's search proxy.
    """
    if predicted_saving_s <= 0.0 or predicted_saving_s * horizon < cost_s:
        return False
    return True


# ----------------------------------------------------------- self-check


def main() -> int:
    # -- cadence -------------------------------------------------------
    assert not cadence_due(0, 8), "never at step 0"
    assert not cadence_due(4, 8)
    assert cadence_due(8, 8) and cadence_due(16, 8)
    assert not cadence_due(8, 0), "every = 0 disables placement"

    # -- EWMA: first observation seeds, then exponential decay ---------
    ewma = GateLoadEwma(1, 2, 0.25)
    ewma.observe([[8.0, 0.0]])
    assert ewma.loads == [[8.0, 0.0]], "first observation seeds directly"
    ewma.observe([[0.0, 8.0]])
    assert ewma.loads == [[0.75 * 8.0, 0.25 * 8.0]], ewma.loads
    ewma.observe([[0.0, 8.0]])
    want0 = 0.75 * 0.75 * 8.0
    want1 = 0.75 * (0.25 * 8.0) + 0.25 * 8.0
    assert abs(ewma.loads[0][0] - want0) < 1e-15
    assert abs(ewma.loads[0][1] - want1) < 1e-15
    assert ewma.steps == 3

    # -- amortisation gate ---------------------------------------------
    assert migration_gate(1e-3, 100.0, 5e-2), "0.1s saved vs 0.05s cost"
    assert not migration_gate(1e-3, 100.0, 2e-1), "does not amortise"
    assert not migration_gate(0.0, 1e9, 0.0), "zero saving never migrates"
    assert not migration_gate(-1e-3, 1e9, 0.0), "regressions never migrate"
    # boundary: saving * horizon == cost_s is accepted (strict <)
    assert migration_gate(1e-3, 100.0, 1e-1)

    print("mirrors.placement_gate: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
