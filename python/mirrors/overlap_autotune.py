#!/usr/bin/env python3
"""Mirror of the chunk-count autotuner (rust/src/overlap/autotune.rs).

The decision rule: sweep ``CHUNK_SWEEP``, price each pipeline with the
caller-supplied ``cost_of(k)``, and keep the cheapest — where "cheaper"
means beating the incumbent by more than 1e-9 relative
(``cost < best * (1 - 1e-9)``), so near-ties keep the smaller ``k``
(less launch/synchronisation overhead for the same clock). ``k = 1`` is
in the sweep, so the winner never prices above the serial clock.

Run ``python3 -m mirrors.overlap_autotune`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import Callable, Tuple

# overlap/chunk.rs: the candidate chunk counts
CHUNK_SWEEP = (1, 2, 4, 8, 16)


def autotune_k(cost_of: Callable[[int], float]) -> Tuple[int, float]:
    """Sweep CHUNK_SWEEP and return ``(k, makespan_s)`` of the winner.

    Selection is exactly autotune.rs: a candidate replaces the incumbent
    iff ``cost < best * (1 - 1e-9)``; the sweep ascends, so ties and
    near-ties resolve to the smaller chunk count.
    """
    best = None
    for k in CHUNK_SWEEP:
        cost = cost_of(k)
        if best is None or cost < best[1] * (1.0 - 1e-9):
            best = (k, cost)
    assert best is not None, "CHUNK_SWEEP is non-empty"
    return best


# ----------------------------------------------------------- self-check


def _pipeline_toy(alpha: float, volume_s: float, fixed_s: float) -> Callable[[int], float]:
    """A toy chunked-pipeline clock with the real trade-off shape: each
    of the k chunks re-pays the path latency α, the byte volume divides
    by k and overlaps all but one chunk's worth with ``fixed_s``."""

    def cost(k: int) -> float:
        chunk_s = alpha + volume_s / k
        return chunk_s + max(fixed_s, (k - 1) * chunk_s)

    return cost


def main() -> int:
    # -- alpha-dominated steps stay serial -----------------------------
    k, cost = autotune_k(_pipeline_toy(1.0, 0.01, 0.5))
    assert k == 1, k
    assert cost == _pipeline_toy(1.0, 0.01, 0.5)(1)

    # -- bandwidth-dominated steps chunk, and beat serial --------------
    price = _pipeline_toy(1e-4, 2.0, 2.0)
    k, cost = autotune_k(price)
    assert k > 1, k
    assert cost < price(1)

    # -- winner never prices above serial (k = 1 is in the sweep) ------
    for args in [(0.5, 0.1, 0.2), (1e-3, 8.0, 4.0), (0.1, 0.1, 0.05)]:
        price = _pipeline_toy(*args)
        _, cost = autotune_k(price)
        assert cost <= price(1) + 1e-18

    # -- near-ties keep the smaller k ----------------------------------
    k, _ = autotune_k(lambda k: 1.0)  # exact tie across the sweep
    assert k == 1, k
    k, _ = autotune_k(lambda k: 1.0 - (5e-10 if k == 4 else 0.0))
    assert k == 1, "a 5e-10 relative win is inside the 1e-9 tie band"
    k, _ = autotune_k(lambda k: 1.0 - (5e-9 if k == 4 else 0.0))
    assert k == 4, "a 5e-9 relative win is a real improvement"

    print("mirrors.overlap_autotune: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
