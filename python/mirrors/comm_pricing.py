#!/usr/bin/env python3
"""Mirror of the α-β communication cost engine (rust/src/comm/engine.rs).

The rust engine prices a P×P byte matrix on a topology whose per-pair
paths are lists of directed-link *slots* (``2*edge + dir``), each slot
carrying an ``alpha`` (latency), ``beta`` (seconds/byte) and a
``contended`` flag. The decision math mirrored here, IEEE-754 double
semantics throughout:

* ``contended_time`` — one delivery under a live flow census: α
  accumulates along the path, the slowest hop's β is inflated by its
  concurrent flows (non-contended point-to-point slots never are);
* ``pair_times`` — the contention exchange model: census all live
  cross-device deliveries, then price each pair against it;
* ``exchange_time`` — completion time of the whole exchange. Self pairs
  are local copies that overlap the network phase and contribute only
  their excess: ``net + max(copy - net, 0)``.

Run ``python3 -m mirrors.comm_pricing`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Sequence, Tuple


class Topology:
    """Slot-level view of a topology: per-pair slot paths + link tables.

    ``paths[(i, j)]`` lists the directed-link slots a delivery i→j
    crosses; self pairs use the local-copy constants instead.
    """

    def __init__(
        self,
        p: int,
        paths: Dict[Tuple[int, int], Sequence[int]],
        slot_alpha: Sequence[float],
        slot_beta: Sequence[float],
        slot_contended: Sequence[bool],
        local_alpha: float,
        local_beta: float,
    ):
        self.p = p
        self.paths = paths
        self.slot_alpha = list(slot_alpha)
        self.slot_beta = list(slot_beta)
        self.slot_contended = list(slot_contended)
        self.local_alpha = local_alpha
        self.local_beta = local_beta

    def pair_slots(self, i: int, j: int) -> Sequence[int]:
        return self.paths[(i, j)]

    def n_slots(self) -> int:
        return len(self.slot_alpha)

    def pair_time(self, i: int, j: int, nbytes: float) -> float:
        """Isolated delivery time: α_ij + β_ij · bytes (no contention)."""
        if i == j:
            return self.local_alpha + self.local_beta * nbytes
        alpha = 0.0
        beta = 0.0
        for s in self.pair_slots(i, j):
            alpha += self.slot_alpha[s]
            beta = max(beta, self.slot_beta[s])
        return alpha + beta * nbytes


def census_add(topo: Topology, census: List[int], i: int, j: int) -> None:
    for s in topo.pair_slots(i, j):
        census[s] += 1


def census_sub(topo: Topology, census: List[int], i: int, j: int) -> None:
    for s in topo.pair_slots(i, j):
        census[s] -= 1


def contended_time(
    topo: Topology, census: Sequence[int], i: int, j: int, nbytes: float
) -> float:
    """One delivery's time under a dense flow census (engine.rs).

    α accumulates along the path; the slowest hop's β is inflated by its
    concurrent flows. Non-contended point-to-point slots never contend.
    """
    alpha = 0.0
    slow = 0.0
    for s in topo.pair_slots(i, j):
        flows = float(census[s]) if topo.slot_contended[s] else 1.0
        alpha += topo.slot_alpha[s]
        slow = max(slow, topo.slot_beta[s] * flows)
    return alpha + slow * nbytes


def pair_times(topo: Topology, bytes_mat: Sequence[Sequence[float]]) -> List[List[float]]:
    """Per-pair delivery times of a full exchange (contention model)."""
    p = topo.p
    census = [0] * topo.n_slots()
    for i in range(p):
        for j in range(p):
            if i != j and bytes_mat[i][j] > 0.0:
                census_add(topo, census, i, j)
    times = [[0.0] * p for _ in range(p)]
    for i in range(p):
        for j in range(p):
            b = bytes_mat[i][j]
            if b <= 0.0:
                t = 0.0
            elif i == j:
                t = topo.pair_time(i, i, b)
            else:
                t = contended_time(topo, census, i, j, b)
            times[i][j] = t
    return times


def exchange_time(topo: Topology, bytes_mat: Sequence[Sequence[float]]) -> float:
    """Exchange completion time with the self-copy overlap convention.

    The network phase is gated by cross-device deliveries only; a local
    copy contributes just its excess over that phase:
    ``net + max(copy - net, 0)`` (engine.rs ``exchange_time``).
    """
    times = pair_times(topo, bytes_mat)
    net = 0.0
    copy = 0.0
    for i in range(topo.p):
        for j in range(topo.p):
            if i == j:
                copy = max(copy, times[i][j])
            else:
                net = max(net, times[i][j])
    return net + max(copy - net, 0.0)


# ----------------------------------------------------------- self-check


def two_node_tree() -> Topology:
    """[2,2]: four devices, two leaf switches, one contended uplink pair.

    Slots 0–7: device links up/down (dev d up = 2d, down = 2d+1), slots
    8–11: switch uplinks (sw s up = 8+2s, down = 9+2s). A delivery
    crosses: own device link up, [uplink up, peer uplink down when
    crossing nodes], peer device link down.
    """
    dev_a, dev_b = 1e-6, 1e-11  # 100 GB/s device links
    up_a, up_b = 5e-6, 1e-10  # 10 GB/s uplinks
    slot_alpha = [dev_a] * 8 + [up_a] * 4
    slot_beta = [dev_b] * 8 + [up_b] * 4
    slot_contended = [True] * 12
    node = lambda d: d // 2
    paths = {}
    for i in range(4):
        for j in range(4):
            if i == j:
                continue
            path = [2 * i]  # own device link up
            if node(i) != node(j):
                path.append(8 + 2 * node(i))  # own uplink up
                path.append(9 + 2 * node(j))  # peer uplink down
            path.append(2 * j + 1)  # peer device link down
            paths[(i, j)] = path
    return Topology(4, paths, slot_alpha, slot_beta, slot_contended, 0.0, 1e-12)


def main() -> int:
    t = two_node_tree()
    mb = 1e6

    # -- isolated pair: α sums along the path, slowest β gates ---------
    one = [[0.0] * 4 for _ in range(4)]
    one[0][2] = mb  # single cross-node delivery
    got = exchange_time(t, one)
    want = (1e-6 + 5e-6 + 5e-6 + 1e-6) + 1e-10 * mb
    assert abs(got - want) < 1e-18, (got, want)

    # -- contention: two deliveries share dev 0's uplink slot ----------
    two = [[0.0] * 4 for _ in range(4)]
    two[0][2] = mb
    two[0][3] = mb
    # both cross slot 8 (node-0 uplink up) AND slot 0 (dev-0 link up):
    # census 2 inflates the slowest hop's β (uplink) to 2e-10. But note
    # the send side serialises on slot 0 too — uplink stays the gate.
    got = exchange_time(t, two)
    want = (1e-6 + 5e-6 + 5e-6 + 1e-6) + (1e-10 * 2.0) * mb
    assert abs(got - want) < 1e-18, (got, want)

    # -- non-contended slots never inflate -----------------------------
    t_pp = two_node_tree()
    t_pp.slot_contended = [False] * 12
    got = exchange_time(t_pp, two)
    want = (1e-6 + 5e-6 + 5e-6 + 1e-6) + 1e-10 * mb
    assert abs(got - want) < 1e-18, (got, want)

    # -- self-copy convention: only the excess over the net phase ------
    net_and_copy = [[0.0] * 4 for _ in range(4)]
    net_and_copy[0][1] = mb  # intra-node: 2e-6 + 1e-11·1e6 = 1.2e-5
    net_and_copy[2][2] = mb  # local copy: 1e-12·1e6 = 1e-6 < net → free
    net = 2e-6 + 1e-11 * mb
    got = exchange_time(t, net_and_copy)
    assert abs(got - net) < 1e-18, (got, net)
    net_and_copy[2][2] = 2e10  # slow copy: 2e-2 ≫ net → copy gates
    got = exchange_time(t, net_and_copy)
    want = net + (1e-12 * 2e10 - net)
    assert abs(got - want) < 1e-18, (got, want)

    # -- zero-byte pairs cost nothing ----------------------------------
    assert exchange_time(t, [[0.0] * 4 for _ in range(4)]) == 0.0

    print("mirrors.comm_pricing: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
