#!/usr/bin/env python3
"""Mirror of the perturbation schedule + recovery metric (rust/src/perturb/mod.rs).

Two decision rules ride the fault stream end to end and must price the
same on both sides of the language boundary:

* ``straggler_active`` — whether a scripted straggler with window
  ``[start, end)`` and ``flap_period`` is slowing its device at ``step``.
  A zero period holds over the whole window; otherwise the slowdown
  alternates on/off in ``flap_period``-step blocks, starting *on*.
* ``recovery_steps`` — the headline robustness observable: steps from
  fault onset until the step clock first returns within ``tol`` of the
  pre-onset steady state (baseline = mean of the ``window`` steps before
  onset; recovered at the first ``t >= onset`` with
  ``step_s[t] <= baseline * (1 + tol)``). ``None`` when there is no
  pre-onset history or the clock never comes back — the summary JSON
  encodes that as ``recovery_steps: -1``.

Run ``python3 -m mirrors.perturb_recovery`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import Optional, Sequence

# perturb/mod.rs: an omitted window end never closes (usize::MAX there).
OPEN_END = 2**64 - 1

# metrics/mod.rs: the run-log defaults fed to recovery_steps.
RECOVERY_WINDOW = 8
RECOVERY_TOL = 0.05


def straggler_active(step: int, start: int, end: int, flap_period: int) -> bool:
    """Exactly perturb/mod.rs::straggler_active: in-window, and either a
    constant slowdown (period 0) or the even `flap_period`-block."""
    if step < start or step >= end:
        return False
    return flap_period == 0 or ((step - start) // flap_period) % 2 == 0


def recovery_steps(
    step_s: Sequence[float], onset: int, window: int, tol: float
) -> Optional[int]:
    """Exactly perturb/mod.rs::recovery_steps, including the edge cases:
    no pre-onset history (onset 0), onset past the series, or a zero
    baseline window all return None; so does a clock that never returns
    to ``baseline * (1 + tol)``."""
    if onset == 0 or onset > len(step_s) or window == 0:
        return None
    lo = max(onset - window, 0)  # saturating_sub
    base = step_s[lo:onset]
    baseline = sum(base) / len(base)
    for t in range(onset, len(step_s)):
        if step_s[t] <= baseline * (1.0 + tol):
            return t - onset
    return None


# ----------------------------------------------------------- self-check


def main() -> int:
    # -- straggler window edges: [start, end) ---------------------------
    assert not straggler_active(9, 10, 20, 0)
    assert straggler_active(10, 10, 20, 0)
    assert straggler_active(19, 10, 20, 0)
    assert not straggler_active(20, 10, 20, 0)

    # -- an omitted end never closes ------------------------------------
    assert straggler_active(10**9, 10, OPEN_END, 0)

    # -- flapping alternates in period blocks, starting on --------------
    on = [step for step in range(10, 26) if straggler_active(step, 10, 26, 4)]
    assert on == [10, 11, 12, 13, 18, 19, 20, 21], on
    # period 1 toggles every step
    assert straggler_active(10, 10, 20, 1)
    assert not straggler_active(11, 10, 20, 1)
    # the flap phase is anchored at the window start, not step 0
    assert straggler_active(13, 13, 20, 4) and not straggler_active(13, 9, 20, 4)

    # -- recovery: clean series recovers instantly ----------------------
    flat = [1.0] * 20
    assert recovery_steps(flat, 10, RECOVERY_WINDOW, RECOVERY_TOL) == 0

    # -- a bounded spike recovers when it re-enters the 5% band ---------
    series = [1.0] * 10 + [4.0] * 6 + [1.02] * 8
    assert recovery_steps(series, 10, RECOVERY_WINDOW, RECOVERY_TOL) == 6
    # a tighter tolerance pushes recovery past the 1.02 tail entirely
    assert recovery_steps(series, 10, RECOVERY_WINDOW, 0.01) is None

    # -- baseline is the mean of the pre-onset window only --------------
    # window 2 sees [1.0, 3.0] -> baseline 2.0: the 2.05 tail is inside
    # tol; window 1 sees [3.0] -> baseline 3.0 admits the spike at once
    ramp = [9.0] * 8 + [1.0, 3.0] + [2.5] * 4 + [2.05] * 4
    assert recovery_steps(ramp, 10, 2, RECOVERY_TOL) == 4
    assert recovery_steps(ramp, 10, 1, RECOVERY_TOL) == 0

    # -- the None edge cases, exactly as rust prices them ---------------
    assert recovery_steps([2.0, 2.0], 0, RECOVERY_WINDOW, RECOVERY_TOL) is None
    assert recovery_steps([2.0, 2.0], 3, RECOVERY_WINDOW, RECOVERY_TOL) is None
    assert recovery_steps([2.0, 2.0], 1, 0, RECOVERY_TOL) is None
    # onset == len: baseline exists but nothing after it ever recovers
    assert recovery_steps([1.0, 1.0], 2, RECOVERY_WINDOW, RECOVERY_TOL) is None
    # never recovers inside the series
    assert recovery_steps([1.0] * 5 + [9.0] * 5, 5, 4, RECOVERY_TOL) is None

    print("mirrors.perturb_recovery: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
