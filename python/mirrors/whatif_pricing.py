#!/usr/bin/env python3
"""Mirror of the what-if decision math (rust/src/analyze/mod.rs).

Ports the two pure folds the bottleneck report is built from:

* ``blame_fractions`` — normalise raw ``(track, blame_s)`` critical-path
  rows against the step clock (``blame_s / step_s``, 0 on a zero clock)
  and sort most-blamed first with ties broken by track name, so the
  report is total. The fractions of a full blame partition sum to 1.
* ``rank_counterfactuals`` — turn ``(spec, baseline_s, projected_s)``
  re-pricing triples into ranked rows: ``speedup = baseline / projected``
  (0 when the projection collapses to zero — "free" ranks worthless, not
  infinite), sorted by speedup descending, ties by spec.

The perturbation and re-pricing themselves live in the rust cost model
(``step_cost_blamed`` and the ``WhatIf`` projection seams); this mirror
pins the *decision* layer that orders the report. Rows come in and out as
plain dicts so the self-check reads like the rust unit tests. Run
``python3 -m mirrors.whatif_pricing`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Sequence, Tuple

BlameRow = Dict[str, object]  # track, blame_s, blame_frac
CounterfactualRow = Dict[str, object]  # spec, baseline_s, projected_s, speedup


def blame_fractions(rows: Sequence[Tuple[str, float]], step_s: float) -> List[BlameRow]:
    """Normalise and sort blame rows — decision-for-decision the rust
    ``blame_fractions`` (busy_frac is folded in later, outside this fn)."""
    out: List[BlameRow] = [
        {
            "track": track,
            "blame_s": blame_s,
            "blame_frac": blame_s / step_s if step_s > 0.0 else 0.0,
        }
        for track, blame_s in rows
    ]
    out.sort(key=lambda r: (-float(r["blame_s"]), r["track"]))
    return out


def rank_counterfactuals(
    rows: Sequence[Tuple[str, float, float]]
) -> List[CounterfactualRow]:
    """Rank re-pricing triples by projected speedup — decision-for
    -decision the rust ``rank_counterfactuals``."""
    out: List[CounterfactualRow] = [
        {
            "spec": spec,
            "baseline_s": baseline_s,
            "projected_s": projected_s,
            "speedup": baseline_s / projected_s if projected_s > 0.0 else 0.0,
        }
        for spec, baseline_s, projected_s in rows
    ]
    out.sort(key=lambda r: (-float(r["speedup"]), r["spec"]))
    return out


# ----------------------------------------------------------- self-check


def main() -> int:
    # -- blame normalises against the clock and sorts, ties by track ---
    blame = blame_fractions(
        [("dev:0", 1.0), ("link:3", 6.0), ("chan:allreduce", 1.0)], 8.0
    )
    assert [r["track"] for r in blame] == ["link:3", "chan:allreduce", "dev:0"]
    assert blame[0]["blame_frac"] == 0.75
    assert abs(sum(float(r["blame_frac"]) for r in blame) - 1.0) < 1e-12

    # -- zero clock: fractions 0, never a division error ---------------
    assert all(
        r["blame_frac"] == 0.0 for r in blame_fractions([("dev:0", 1.0)], 0.0)
    )

    # -- ranking: best speedup first, ties alphabetical, zero-projection
    #    rows rank last at 0 rather than infinity -----------------------
    ranked = rank_counterfactuals(
        [
            ("alpha0", 10.0, 5.0),
            ("link:1x2", 10.0, 4.0),
            ("dev:0x2", 10.0, 5.0),
            ("perfect-fabric", 10.0, 0.0),
        ]
    )
    assert [r["spec"] for r in ranked] == [
        "link:1x2",
        "alpha0",
        "dev:0x2",
        "perfect-fabric",
    ]
    assert ranked[0]["speedup"] == 2.5
    assert ranked[3]["speedup"] == 0.0

    # -- empty sweeps stay empty ---------------------------------------
    assert blame_fractions([], 1.0) == []
    assert rank_counterfactuals([]) == []

    print("mirrors.whatif_pricing: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
