#!/usr/bin/env python3
"""Mirror of the utilization report fold (rust/src/trace/report.rs).

Ports ``utilization``: fold retained trace spans into per-resource busy
totals and the report headlines, with the exact rust semantics:

* only positive-duration spans (``ph == "X"``) count, and the aggregate
  ``step`` track is excluded;
* rows come out sorted by track name (BTreeMap order);
* ``busy_frac`` is ``busy_s / total_s`` with a zero-clock guard;
* ``straggler_skew`` is max/mean busy over ``dev:`` tracks, ``1.0`` for
  a device-free run or an all-idle mean; devices listed in
  ``dead_devs`` (killed by the fault stream) keep their rows but are
  excluded from the skew so corpses don't read as stragglers;
* ``hottest`` is the top-k tracks by busy time, busiest first, ties
  resolved by ascending track name.

Events are ``(track, ph, dur_s)`` triples with ``ph`` in ``{"X", "i"}``
(the Chrome phase letters the exporter emits). Run
``python3 -m mirrors.trace_utilization`` for the self-check.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Sequence, Tuple

Event = Tuple[str, str, float]  # (track, ph, dur_s)


def _track_is_dead(track: str, dead_devs: Sequence[int]) -> bool:
    """Whether a ``dev:<i>`` track belongs to a whole-window-dead device."""
    if not track.startswith("dev:"):
        return False
    try:
        return int(track[len("dev:") :]) in dead_devs
    except ValueError:
        return False


def utilization(
    events: Sequence[Event], total_s: float, top_k: int, dead_devs: Sequence[int] = ()
) -> Dict[str, object]:
    """Fold spans into the report dict (rows, straggler_skew, hottest,
    total_s) — decision-for-decision the rust ``utilization``."""
    busy: Dict[str, List[float]] = {}
    for track, ph, dur_s in events:
        if ph != "X" or dur_s <= 0.0 or track == "step":
            continue
        slot = busy.setdefault(track, [0.0, 0])
        slot[0] += dur_s
        slot[1] += 1
    rows = [
        {
            "track": track,
            "busy_s": busy_s,
            "busy_frac": busy_s / total_s if total_s > 0.0 else 0.0,
            "spans": spans,
        }
        for track, (busy_s, spans) in sorted(busy.items())
    ]

    dev_busy = [
        r["busy_s"]
        for r in rows
        if str(r["track"]).startswith("dev:") and not _track_is_dead(str(r["track"]), dead_devs)
    ]
    if not dev_busy:
        straggler_skew = 1.0
    else:
        mean = sum(dev_busy) / len(dev_busy)
        # rust folds max from 0.0, not -inf
        peak = 0.0
        for b in dev_busy:
            peak = max(peak, b)
        straggler_skew = peak / mean if mean > 0.0 else 1.0

    by_heat = sorted(((r["busy_s"], r["track"]) for r in rows), key=lambda h: (-h[0], h[1]))
    hottest = [track for _, track in by_heat[:top_k]]

    return {
        "rows": rows,
        "straggler_skew": straggler_skew,
        "hottest": hottest,
        "total_s": total_s,
    }


# ----------------------------------------------------------- self-check


def _spans() -> List[Event]:
    """The rust unit-test fixture: two dev:0 spans, one dev:1, one link,
    a step span, an instant, and a zero-duration span."""
    return [
        ("step", "X", 10.0),
        ("dev:0", "X", 4.0),
        ("dev:0", "X", 2.0),
        ("dev:1", "X", 2.0),
        ("link:3", "X", 5.0),
        ("control", "i", 0.0),
        ("chan:allreduce", "X", 0.0),
    ]


def main() -> int:
    # -- the fold excludes step, instants, and zero-duration spans -----
    rep = utilization(_spans(), 10.0, 2)
    tracks = [r["track"] for r in rep["rows"]]
    assert tracks == ["dev:0", "dev:1", "link:3"], tracks
    assert rep["rows"][0]["busy_s"] == 6.0
    assert rep["rows"][0]["spans"] == 2
    assert rep["rows"][0]["busy_frac"] == 0.6
    assert abs(rep["straggler_skew"] - 1.5) < 1e-15  # dev busy {6, 2}
    assert rep["hottest"] == ["dev:0", "link:3"]
    assert rep["total_s"] == 10.0

    # -- empty / zero-clock runs stay finite ---------------------------
    rep = utilization([], 0.0, 3)
    assert rep["rows"] == []
    assert rep["straggler_skew"] == 1.0
    assert rep["hottest"] == []
    rep = utilization(_spans(), 0.0, 1)
    assert all(r["busy_frac"] == 0.0 for r in rep["rows"])

    # -- heat ties resolve by ascending track name ---------------------
    rep = utilization([("link:9", "X", 1.0), ("link:1", "X", 1.0)], 1.0, 2)
    assert rep["hottest"] == ["link:1", "link:9"]

    # -- top_k truncates, never pads -----------------------------------
    rep = utilization(_spans(), 10.0, 99)
    assert rep["hottest"] == ["dev:0", "link:3", "dev:1"], rep["hottest"]

    # -- dead devices keep their rows but leave the skew ---------------
    corpse: List[Event] = [
        ("dev:0", "X", 6.0),
        ("dev:1", "X", 2.0),
        ("dev:2", "X", 1.0),
    ]
    naive = utilization(corpse, 10.0, 4)
    fixed = utilization(corpse, 10.0, 4, dead_devs=[2])
    assert abs(naive["straggler_skew"] - 2.0) < 1e-15  # 6 / ((6+2+1)/3)
    assert abs(fixed["straggler_skew"] - 1.5) < 1e-15  # 6 / ((6+2)/2)
    assert any(r["track"] == "dev:2" for r in fixed["rows"])
    all_dead = utilization(corpse, 10.0, 4, dead_devs=[0, 1, 2])
    assert all_dead["straggler_skew"] == 1.0

    print("mirrors.trace_utilization: all self-checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
