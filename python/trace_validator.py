#!/usr/bin/env python3
"""Validate a ta-moe Chrome trace export (stdlib-only, CI-runnable).

``ta-moe train --trace run.json`` (and serve) emit Chrome-trace-event
JSON (the ``{"traceEvents": [...]}`` object form Perfetto loads). This
validator checks the contract the exporter promises:

* **schema** — every event has ``ph`` in ``{M, X, i}``, ``pid``/``tid``,
  and a ``ts``; ``X`` spans carry a ``dur``; ``i`` instants carry a
  scope ``s``; ``M`` events are ``thread_name`` metadata naming each
  track exactly once.
* **non-negativity** — no negative timestamp or duration anywhere (the
  simulated clock never runs backwards).
* **non-overlap** — per track, complete spans never overlap: each track
  models one resource (a device, a directed link, a channel), which
  cannot do two things at one simulated instant. Touching endpoints are
  legal.
* **reconciliation** — for every track in
  ``otherData.timeline_busy_s`` (the overlap engine's independent
  ``Timeline::busy()`` accounting), the span durations on that track
  sum to the same total within ``1e-9`` seconds. The two numbers come
  from different accumulation paths in the crate, so this is a real
  cross-check, not a tautology; tracks without a busy entry (``step``,
  ``serial``, ``link:*``, ``migrate``, ``fetch``) are exempt.

Usage::

    python3 python/trace_validator.py run.json [more.json ...]
    python3 python/trace_validator.py --selftest

Exit code 0 when every file passes, 1 otherwise.
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

PHASES = {"M", "X", "i"}
RECONCILE_EPS_S = 1e-9
OVERLAP_EPS_US = 1e-3  # 1e-9 s on the microsecond timestamps


def validate(trace: object, name: str = "<trace>") -> List[str]:
    """Return a list of violations (empty = valid)."""
    errs: List[str] = []
    if not isinstance(trace, dict) or not isinstance(trace.get("traceEvents"), list):
        return [f"{name}: top level must be an object with a traceEvents array"]
    events = trace["traceEvents"]

    track_of: Dict[object, str] = {}
    spans: Dict[object, List[Tuple[float, float]]] = {}
    for i, ev in enumerate(events):
        where = f"{name}: event {i}"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in PHASES:
            errs.append(f"{where}: ph {ph!r} not in {sorted(PHASES)}")
            continue
        if "pid" not in ev or "tid" not in ev:
            errs.append(f"{where}: missing pid/tid")
            continue
        tid = ev["tid"]
        if ph == "M":
            if ev.get("name") != "thread_name":
                errs.append(f"{where}: metadata event is not thread_name")
                continue
            track = (ev.get("args") or {}).get("name")
            if not isinstance(track, str):
                errs.append(f"{where}: thread_name args.name missing")
            elif tid in track_of:
                errs.append(f"{where}: duplicate thread_name for tid {tid}")
            else:
                track_of[tid] = track
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: ts {ts!r} must be a non-negative number")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: dur {dur!r} must be a non-negative number")
                continue
            spans.setdefault(tid, []).append((float(ts), float(ts) + float(dur)))
        else:  # "i"
            if ev.get("s") not in ("t", "p", "g"):
                errs.append(f"{where}: instant missing scope s")

    for tid, tid_spans in spans.items():
        if tid not in track_of:
            errs.append(f"{name}: tid {tid} has spans but no thread_name metadata")

    # -- non-overlap per track -----------------------------------------
    for tid, tid_spans in sorted(spans.items(), key=lambda kv: str(kv[0])):
        track = track_of.get(tid, f"tid {tid}")
        ordered = sorted(tid_spans)
        for (a0, a1), (b0, b1) in zip(ordered, ordered[1:]):
            if b0 < a1 - OVERLAP_EPS_US:
                errs.append(
                    f"{name}: track {track!r}: span [{b0}, {b1}]us overlaps "
                    f"[{a0}, {a1}]us"
                )
                break  # one report per track keeps the output readable

    # -- reconciliation against Timeline::busy() -----------------------
    busy = (trace.get("otherData") or {}).get("timeline_busy_s") or {}
    if not isinstance(busy, dict):
        errs.append(f"{name}: otherData.timeline_busy_s must be an object")
        busy = {}
    tid_of_track = {t: tid for tid, t in track_of.items()}
    for track, busy_s in sorted(busy.items()):
        if not isinstance(busy_s, (int, float)) or busy_s < 0:
            errs.append(f"{name}: timeline_busy_s[{track!r}] = {busy_s!r} invalid")
            continue
        tid = tid_of_track.get(track)
        span_sum_s = sum(b - a for a, b in spans.get(tid, [])) / 1e6
        if abs(span_sum_s - busy_s) > RECONCILE_EPS_S:
            errs.append(
                f"{name}: track {track!r}: span sum {span_sum_s!r}s does not "
                f"reconcile with timeline busy {busy_s!r}s (eps {RECONCILE_EPS_S})"
            )
    return errs


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as fh:
            trace = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path}: unreadable trace: {exc}"]
    return validate(trace, path)


# ----------------------------------------------------------- self-test


def _meta(tid: int, track: str) -> dict:
    return {"ph": "M", "name": "thread_name", "pid": 1, "tid": tid, "args": {"name": track}}


def _span(tid: int, ts: float, dur: float) -> dict:
    return {"ph": "X", "name": "x", "cat": "c", "pid": 1, "tid": tid, "ts": ts, "dur": dur}


def _instant(tid: int, ts: float) -> dict:
    return {"ph": "i", "name": "m", "cat": "c", "pid": 1, "tid": tid, "ts": ts, "s": "t"}


def selftest() -> int:
    good = {
        "traceEvents": [
            _meta(1, "step"),
            _meta(2, "dev:0"),
            _span(1, 0.0, 10.0),
            _span(2, 0.0, 4.0),
            _span(2, 4.0, 2.0),  # touching endpoints are legal
            _instant(1, 3.0),
        ],
        "displayTimeUnit": "ms",
        "otherData": {"timeline_busy_s": {"dev:0": 6e-6}},
    }
    assert validate(good) == [], validate(good)

    # tracks without a busy entry are exempt from reconciliation
    exempt = json.loads(json.dumps(good))
    exempt["otherData"]["timeline_busy_s"] = {}
    assert validate(exempt) == []

    # a busy total off by more than 1e-9 s must fail
    bad = json.loads(json.dumps(good))
    bad["otherData"]["timeline_busy_s"]["dev:0"] = 6e-6 + 2e-9
    assert any("reconcile" in e for e in validate(bad)), validate(bad)

    # overlapping spans on one track must fail
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].append(_span(2, 3.0, 2.0))
    assert any("overlaps" in e for e in validate(bad)), validate(bad)

    # negative duration / timestamp must fail
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].append(_span(1, 11.0, -1.0))
    assert any("dur" in e for e in validate(bad))
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].append(_instant(1, -0.5))
    assert any("ts" in e for e in validate(bad))

    # unknown phase letters, missing metadata, and bad top levels fail
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].append({"ph": "B", "pid": 1, "tid": 1, "ts": 0.0})
    assert any("ph" in e for e in validate(bad))
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].remove(_meta(2, "dev:0"))
    assert any("no thread_name" in e for e in validate(bad))
    assert validate([]) != []
    assert validate({"traceEvents": 3}) != []

    # duplicate thread_name for one tid fails
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].insert(1, _meta(1, "other"))
    assert any("duplicate" in e for e in validate(bad))

    print("trace_validator: all self-checks passed")
    return 0


def main(argv: List[str]) -> int:
    if not argv or argv == ["--selftest"]:
        if argv:
            return selftest()
        print(__doc__)
        return 2
    rc = 0
    for path in argv:
        errs = validate_file(path)
        for e in errs:
            print(e, file=sys.stderr)
        if errs:
            rc = 1
        else:
            print(f"{path}: valid chrome trace")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
