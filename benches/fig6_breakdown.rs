//! Figure 6 reproduction.
//!
//! (a) Communication/computation breakdown and the communication speedup
//!     of TA-MoE over even dispatch on cluster C at 8–64 experts
//!     (paper: 1.16x–6.4x, maximum at 32 experts on four cross-switch
//!     nodes).
//! (b) The dispatch distribution of ranks 0–7: most tokens go to
//!     low-overhead nearby ranks (the "ladder" shape), from a *real*
//!     trained gate on the wide16 artifact.
//!
//! ```bash
//! cargo bench --bench fig6_breakdown
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, step_cost, FastMoeEven, ModelShape, TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn cfg_for(p: usize) -> ModelCfg {
    ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f: 4096,
        heads: 16,
        vocab: 50_000,
        batch: 6,
        seq: 1024,
        k: 1,
        cap_factor: 1.0,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: p,
        capacity: 12_288,
        tokens_per_dev: 6144,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    // ---- (a) breakdown at paper scale on cluster C ------------------------
    println!("Figure 6(a): comm/compute breakdown on cluster C (GPT-Medium scale)\n");
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    let mut t = Table::new(&[
        "experts", "even comm", "even compute", "ta comm", "comm speedup",
    ]);
    let mut payload = BTreeMap::new();
    let mut speedups = Vec::new();
    for p in [8usize, 16, 32, 64] {
        let topo = presets::cluster_c(p / 8);
        let cfg = cfg_for(p);
        let flops = device_flops('C');
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let c_even = step_cost(&shape, &topo, &even, 1, flops, A2aAlgo::Direct);
        let c_ta = step_cost(&shape, &topo, &ta, 1, flops, A2aAlgo::Direct);
        let comm_even = c_even.a2a_s + c_even.allreduce_s;
        let comm_ta = c_ta.a2a_s + c_ta.allreduce_s;
        let s = comm_even / comm_ta;
        speedups.push((p, s));
        payload.insert(format!("comm_speedup_{p}"), Json::Num(s));
        t.row(&[
            p.to_string(),
            format!("{:.1}ms", comm_even * 1e3),
            format!("{:.1}ms", c_even.compute_s * 1e3),
            format!("{:.1}ms", comm_ta * 1e3),
            format!("{s:.2}x"),
        ]);
    }
    t.print();
    let max = speedups.iter().cloned().fold((0, 0.0), |a, b| if b.1 > a.1 { b } else { a });
    println!(
        "\nmax comm speedup: {:.2}x at {} experts (paper: up to 6.4x, max at 32 experts\n\
         on four cross-switch nodes); multi-node entries must exceed 1.16x",
        max.1, max.0
    );
    for (p, s) in &speedups {
        if *p > 8 {
            assert!(*s > 1.1, "comm speedup at {p} experts too small: {s}");
        }
    }

    // ---- (b) trained dispatch distribution, ranks 0–7 ---------------------
    let steps = common::env_steps(120);
    println!("\nFigure 6(b): dispatch of ranks 0-7 after {steps} TA-MoE steps (wide16)\n");
    let (_, counts) = common::train_arm(
        "wide16_switch",
        "C",
        Box::new(TaMoe { norm: Norm::L1 }),
        steps,
        42,
        0,
    )?;
    let topo = ta_moe::config::topology_for("C", 16);
    let mut t = Table::new(&["rank", "on-node tokens", "off-node tokens", "on-node %"]);
    let mut ladder_ok = 0;
    for i in 0..8 {
        let row = counts.row(i);
        let on: f64 = row
            .iter()
            .enumerate()
            .filter(|(e, _)| topo.same_node(i, *e))
            .map(|(_, v)| v)
            .sum();
        let total: f64 = row.iter().sum();
        let frac = on / total;
        // uniform would put 1/n_nodes on-node
        if frac > 1.0 / topo.n_nodes() as f64 {
            ladder_ok += 1;
        }
        t.row(&[
            i.to_string(),
            format!("{on:.1}"),
            format!("{:.1}", total - on),
            format!("{:.0}%", frac * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nladder check: {ladder_ok}/8 ranks dispatch above the uniform on-node share \
         (paper: \"most of the data of Rank 0-7 are dispatched to low-overheads nearby ranks\")"
    );
    payload.insert("ladder_ranks".into(), Json::Num(ladder_ok as f64));
    record_jsonl("fig6_breakdown", &Json::Obj(payload));
    Ok(())
}
