//! Sweep: chunk count × a2a plan × cluster on the overlap timeline.
//!
//! For every cluster arm and a2a algorithm, price one converged TA-MoE
//! step at each chunk count in the autotuner's sweep and report the
//! overlapped makespan and the exposed-communication fraction
//! (exposed a2a / total a2a) — the overlap-layer companion to
//! `ablation_a2a`: *how much of the wire time the pipeline hides* matters
//! alongside what the pattern is and how it executes.
//!
//! Shape assertions:
//! * `k = 1` reproduces the serial step price to 1e-12 on every arm;
//! * the autotuned clock never exceeds the serial clock, and never
//!   exceeds any swept fixed-`k` clock, on every arm;
//! * the overlapped clock never drops below the analytic phase floor
//!   `max(compute, allreduce)`.
//!
//! ```bash
//! cargo bench --bench overlap_sweep
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench overlap_sweep   # CI smoke
//! ```
//!
//! Quick mode sweeps only the 2-node cluster-C arm with the direct and
//! BvN plans; all assertions stay enforced.

use std::collections::BTreeMap;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, step_cost, step_cost_overlapped, ModelShape, TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::overlap::{OverlapMode, CHUNK_SWEEP};
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn cfg_for(p: usize) -> ModelCfg {
    ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f: 4096,
        heads: 16,
        vocab: 50_000,
        batch: 6,
        seq: 1024,
        k: 1,
        cap_factor: 1.0,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: p,
        capacity: 12_288,
        tokens_per_dev: 6144,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}

fn main() {
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    println!("Overlap sweep: chunk count × a2a plan × cluster (per-step seconds)\n");
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    let mut payload = BTreeMap::new();

    let arms: &[(&str, usize)] =
        if quick { &[("C", 2)] } else { &[("B", 2), ("C", 2), ("C", 4)] };
    let algos: &[A2aAlgo] = if quick {
        &[A2aAlgo::Direct, A2aAlgo::Scheduled(ta_moe::comm::ScheduleKind::Bvn)]
    } else {
        &[
            A2aAlgo::Direct,
            A2aAlgo::Hierarchical,
            A2aAlgo::Scheduled(ta_moe::comm::ScheduleKind::Rotation),
            A2aAlgo::Scheduled(ta_moe::comm::ScheduleKind::Bvn),
        ]
    };

    for &(cluster, nodes) in arms {
        let topo = presets::by_name(cluster, nodes).unwrap();
        let p = topo.p();
        let cfg = cfg_for(p);
        let flops = device_flops(cluster.chars().next().unwrap());
        let counts = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        println!("== cluster {cluster} × {nodes} nodes (P={p}), ta-moe dispatch ==");
        let mut t = Table::new(&[
            "a2a", "serial", "k=1", "k=2", "k=4", "k=8", "k=16", "auto (k)",
            "exposed a2a",
        ]);
        for &algo in algos {
            if algo.validate_for(p).is_err() {
                continue;
            }
            let serial = step_cost(&shape, &topo, &counts, 1, flops, algo);
            let mut cells = vec![algo.name(), format!("{:.2}ms", serial.serial_total() * 1e3)];
            let mut best_fixed = f64::INFINITY;
            for k in CHUNK_SWEEP {
                let c = step_cost_overlapped(
                    &shape,
                    &topo,
                    &counts,
                    1,
                    flops,
                    algo,
                    OverlapMode::Fixed(k),
                    None,
                    None,
                );
                cells.push(format!("{:.2}ms", c.step_s() * 1e3));
                best_fixed = best_fixed.min(c.step_s());
                if k == 1 {
                    // the serial-equality bar, on every arm
                    let (got, want) = (c.step_s(), serial.serial_total());
                    assert!(
                        (got - want).abs() <= 1e-12 * want,
                        "{cluster}x{nodes}/{algo}: k=1 {got} != serial {want}"
                    );
                }
                let floor = serial.compute_s.max(serial.allreduce_s);
                assert!(
                    c.step_s() >= floor * (1.0 - 1e-9),
                    "{cluster}x{nodes}/{algo} k={k}: below the phase floor"
                );
            }
            let auto = step_cost_overlapped(
                &shape,
                &topo,
                &counts,
                1,
                flops,
                algo,
                OverlapMode::Auto,
                None,
                None,
            );
            cells.push(format!("{:.2}ms ({})", auto.step_s() * 1e3, auto.chunks));
            let exposed_frac = if auto.a2a_s > 0.0 {
                auto.exposed_a2a_s / auto.a2a_s
            } else {
                0.0
            };
            cells.push(format!("{:.0}%", exposed_frac * 100.0));
            t.row(&cells);

            // the autotuner's guarantee: never above serial, never above
            // any swept fixed k
            assert!(
                auto.step_s() <= serial.serial_total() * (1.0 + 1e-9),
                "{cluster}x{nodes}/{algo}: auto above serial"
            );
            assert!(
                auto.step_s() <= best_fixed * (1.0 + 1e-9),
                "{cluster}x{nodes}/{algo}: auto above the best fixed k"
            );
            payload.insert(
                format!("{cluster}{nodes}_{}_overlap_eff", algo.name()),
                Json::Num(auto.overlap_efficiency()),
            );
            payload.insert(
                format!("{cluster}{nodes}_{}_auto_k", algo.name()),
                Json::Num(auto.chunks as f64),
            );
            payload.insert(
                format!("{cluster}{nodes}_{}_exposed_frac", algo.name()),
                Json::Num(exposed_frac),
            );
        }
        t.print();
        println!();
    }
    println!(
        "The overlapped clock interpolates the serial sum (k=1) and the\n\
         busiest-resource bound (large k), re-paying per-chunk latency —\n\
         the autotuner picks the knee per (topology, plan)."
    );
    record_jsonl("overlap_sweep", &Json::Obj(payload));
}
