//! Trace-layer overhead budget (EXPERIMENTS.md §Trace overhead): the
//! `--trace off` path must cost *nothing* — no tracer, no events, a
//! byte-identical summary — and each trace level's recording overhead on
//! the host must stay within its budget relative to the untraced
//! session.
//!
//! ```bash
//! cargo bench --bench trace_overhead
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench trace_overhead   # CI smoke
//! ```

use std::collections::BTreeMap;
use ta_moe::coordinator::SessionBuilder;
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::trace::{chrome_trace, TraceLevel};
use ta_moe::util::bench::{record_jsonl, time_it, Table};
use ta_moe::util::json::Json;

const STEPS: usize = 30;

fn run_session(trace: Option<TraceLevel>) -> ta_moe::coordinator::Session {
    let cfg = ModelCfg::preset("tiny4").expect("builtin preset");
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .cluster("table1")
        .a2a_named("sched:rot")
        .overlap_named("auto")
        .seed(5);
    if let Some(level) = trace {
        b = b.trace_level(level);
    }
    let mut s = b.build().unwrap();
    s.run(STEPS).unwrap();
    s
}

fn main() {
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let (warmup, samples) = if quick { (1, 3) } else { (3, 15) };

    // --- the zero-cost contract, asserted before any timing ---
    let off = run_session(None);
    assert!(off.tracer().is_none(), "trace off must not even allocate a tracer");
    let off_summary = off.log().summary_json().to_string_compact();
    for level in [TraceLevel::Step, TraceLevel::Phase, TraceLevel::Chunk] {
        let on = run_session(Some(level));
        let tr = on.tracer().expect("tracer attached");
        assert!(!tr.events().is_empty(), "{level}: a traced run must record events");
        assert_eq!(
            on.log().summary_json().to_string_compact(),
            off_summary,
            "{level}: tracing must not perturb the priced run"
        );
    }

    let mut t = Table::new(&["trace mode", "mean/run", "overhead", "samples"]);
    let mut payload = BTreeMap::new();
    let mut bench = |f: &mut dyn FnMut()| time_it(f, warmup, samples);

    let base = bench(&mut || {
        std::hint::black_box(run_session(None));
    });
    t.row(&["off".into(), format!("{:.0}us", base.mean_us()), "1.00x".into(), base.iters.to_string()]);
    payload.insert("off_us".to_string(), Json::Num(base.mean_us()));

    let mut worst = 1.0f64;
    for level in [TraceLevel::Step, TraceLevel::Phase, TraceLevel::Chunk] {
        let s = bench(&mut || {
            std::hint::black_box(run_session(Some(level)));
        });
        let ratio = s.mean_us() / base.mean_us();
        worst = worst.max(ratio);
        t.row(&[
            level.to_string(),
            format!("{:.0}us", s.mean_us()),
            format!("{ratio:.2}x"),
            s.iters.to_string(),
        ]);
        payload.insert(format!("{level}_us"), Json::Num(s.mean_us()));
        payload.insert(format!("{level}_ratio"), Json::Num(ratio));
    }
    // export cost rides on top of the chunk-level run
    let traced = run_session(Some(TraceLevel::Chunk));
    let s = bench(&mut || {
        std::hint::black_box(chrome_trace(traced.tracer().unwrap()).to_string_compact());
    });
    t.row(&[
        "chunk export".into(),
        format!("{:.0}us", s.mean_us()),
        format!("{:.2}x", s.mean_us() / base.mean_us()),
        s.iters.to_string(),
    ]);
    payload.insert("export_us".to_string(), Json::Num(s.mean_us()));

    // the budget: full-detail recording ≤ 2x the untraced session on this
    // tiny host-bound scenario (real runs are cheaper still: pricing per
    // step grows with P while recording stays proportional to events).
    // Quick mode still checks a slack bound so CI catches gross
    // regressions without flaking on noisy shared runners.
    let budget = if quick { 6.0 } else { 2.0 };
    assert!(
        worst <= budget,
        "trace-on overhead {worst:.2}x exceeds the {budget:.1}x budget"
    );

    t.print();
    println!(
        "\n--trace off is asserted byte-identical and tracer-free; recording\n\
         at every level must stay within {budget:.1}x of the untraced session.\n\
         Budgets + history: EXPERIMENTS.md §Trace overhead{}",
        if quick { "  [quick mode]" } else { "" }
    );
    record_jsonl("trace_overhead", &Json::Obj(payload));
}
