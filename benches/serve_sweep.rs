//! Sweep: arrival trace × cache capacity × cache policy × cluster on the
//! continuous-batching serving simulator.
//!
//! For every cluster arm and trace kind, serve the same seeded request
//! trace through both cache policies at increasing device capacities and
//! report goodput, tail TTFT, cache hit rate, and total weight-fetch
//! time — the serving companion to `overlap_sweep`: *what the expert
//! working set costs on the wire* matters alongside what each step costs.
//!
//! Shape assertions:
//! * the cache-oblivious access stream makes the hit rate monotone in
//!   capacity for both policies, and goodput never degrades with more
//!   capacity (fetch traffic only shrinks);
//! * a full-size cache leaves only compulsory misses, so its fetch bill
//!   is negligible next to the constrained arm's;
//! * topology-aware dispatch serves at least the even baseline's goodput
//!   on the 2×2 tree.
//!
//! ```bash
//! cargo bench --bench serve_sweep
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench serve_sweep   # CI smoke
//! ```
//!
//! Quick mode sweeps only the Table-1 tree under the bursty trace; all
//! assertions stay enforced.

use std::collections::BTreeMap;
use ta_moe::serve::{CachePolicy, ServeBuilder, ServeSession, TraceConfig, TraceKind};
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

const E_PER_DEV: usize = 4;

fn serve(
    cluster: &str,
    kind: TraceKind,
    policy: &str,
    cap: usize,
    cache: CachePolicy,
    quick: bool,
) -> ServeSession {
    let mut s = ServeBuilder::new()
        .preset("tiny4")
        .experts_per_dev(E_PER_DEV)
        .cluster(cluster)
        .policy_named(policy)
        .trace(TraceConfig {
            kind,
            rate_rps: 50.0,
            n_requests: if quick { 32 } else { 64 },
            seed: 17,
            prompt_mean: 32,
            output_mean: 16,
        })
        .cache_cap(cap)
        .cache_policy(cache)
        .slo_s(0.2)
        .build()
        .unwrap();
    s.run(1_000_000).unwrap();
    s
}

fn main() {
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    println!("Serve sweep: trace × cache capacity × policy × cluster\n");
    let mut payload = BTreeMap::new();

    let clusters: &[&str] = if quick { &["table1"] } else { &["table1", "C"] };
    let traces: &[TraceKind] = if quick {
        &[TraceKind::Bursty]
    } else {
        &[TraceKind::Poisson, TraceKind::Bursty, TraceKind::Diurnal]
    };
    let caps: &[usize] = &[1, 2, E_PER_DEV];

    for &cluster in clusters {
        for &kind in traces {
            println!("== cluster {cluster}, {kind} trace, ta-moe dispatch ==");
            let mut t = Table::new(&[
                "cache", "cap", "goodput", "ttft p99", "hit rate", "fetch",
            ]);
            for cache in CachePolicy::ALL {
                let mut prev_hit = -1.0;
                let mut prev_goodput = -1.0;
                let mut fetch_constrained = 0.0;
                for &cap in caps {
                    let s = serve(cluster, kind, "ta-moe", cap, cache, quick);
                    let log = s.log();
                    let hit = log.cache_hit_rate();
                    let goodput = s.goodput();
                    let fetch: f64 = log.records.iter().map(|r| r.sim_fetch_s).sum();
                    let p99 = log.ttft_percentile(99.0).unwrap();
                    t.row(&[
                        cache.to_string(),
                        format!("{cap}/{E_PER_DEV}"),
                        format!("{goodput:.0} tok/s"),
                        format!("{:.3}ms", p99 * 1e3),
                        format!("{:.0}%", hit * 100.0),
                        format!("{:.3}ms", fetch * 1e3),
                    ]);

                    // capacity monotonicity: the access stream is
                    // cache-oblivious, so a bigger cache only gains
                    assert!(
                        hit >= prev_hit,
                        "{cluster}/{kind}/{cache}: hit rate fell {prev_hit:.3} -> {hit:.3} at cap {cap}"
                    );
                    assert!(
                        goodput >= prev_goodput * (1.0 - 1e-9),
                        "{cluster}/{kind}/{cache}: goodput fell {prev_goodput:.1} -> {goodput:.1} at cap {cap}"
                    );
                    (prev_hit, prev_goodput) = (hit, goodput);
                    if cap == caps[0] {
                        fetch_constrained = fetch;
                    }
                    if cap == E_PER_DEV {
                        // full capacity: compulsory misses only
                        assert!(
                            fetch <= fetch_constrained,
                            "{cluster}/{kind}/{cache}: full cache fetches more than the constrained one"
                        );
                        payload.insert(
                            format!("{cluster}_{kind}_{cache}_full_hit_rate"),
                            Json::Num(hit),
                        );
                    }
                    payload.insert(
                        format!("{cluster}_{kind}_{cache}_cap{cap}_goodput"),
                        Json::Num(goodput),
                    );
                }
            }
            t.print();
            println!();
        }
    }

    // the paper's claim, restated for serving: topology-aware dispatch
    // clears at least the even baseline's goodput on the tree
    let kind = TraceKind::Bursty;
    let ta = serve("table1", kind, "ta-moe", 2, CachePolicy::EwmaPrioritized, quick);
    let even = serve("table1", kind, "fastmoe", 2, CachePolicy::Lru, quick);
    println!(
        "table1 bursty, cap 2/{E_PER_DEV}: ta-moe {:.0} tok/s vs even {:.0} tok/s",
        ta.goodput(),
        even.goodput()
    );
    assert!(
        ta.goodput() >= even.goodput() * (1.0 - 1e-9),
        "ta-moe goodput {:.1} below even baseline {:.1} on the tree",
        ta.goodput(),
        even.goodput()
    );
    payload.insert("table1_bursty_tamoe_goodput".into(), Json::Num(ta.goodput()));
    payload.insert("table1_bursty_even_goodput".into(), Json::Num(even.goodput()));

    println!(
        "\nA constrained cache turns remote experts into wire traffic; the\n\
         topology-aware route keeps the working set local and the EWMA\n\
         policy keeps the hot tail resident."
    );
    record_jsonl("serve_sweep", &Json::Obj(payload));
}
