//! L3 hot-path micro-benchmarks (the EXPERIMENTS.md §Perf baseline): the
//! dispatch solver, penalty construction, the contention cost engine, the
//! BvN schedule synthesizer, and the coordinator's per-step host work.
//! These are the pure-rust pieces that run every step or every topology
//! change; the targets and before/after history live in EXPERIMENTS.md
//! §Perf.
//!
//! ```bash
//! cargo bench --bench solver_hotpath
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench solver_hotpath   # CI smoke
//! ```
//!
//! The P sweep (16/32/64/128 on cluster C) makes asymptotic regressions of
//! `exchange_time` and `bvn_schedule` visible, and the cached-vs-cold
//! `step_cost` rows show what the step-level `PlanCache` saves once the
//! dispatch pattern has converged.

use std::collections::BTreeMap;
use ta_moe::comm::{bvn_schedule, A2aAlgo, CostEngine, ScheduleKind};
use ta_moe::coordinator::{
    converged_counts, device_flops, step_cost, step_cost_cached, ModelShape, PlanCache,
    TaMoe, PLAN_CACHE_TOL,
};
use ta_moe::dispatch::{
    penalty_weights, proportional_caps, target_pattern, DispatchProblem, Norm,
};
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, time_it, Table};
use ta_moe::util::json::Json;

fn main() {
    // CI quick mode: exercise every row with a handful of samples instead
    // of a statistically meaningful run
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let (warmup, samples) = if quick { (1, 3) } else { (3, 20) };

    let topo64 = presets::cluster_c(8); // 64 devices
    let prob = DispatchProblem { k: 1, s: 6144, e_per_dev: 1, elem_bytes: 4096 };
    let tp = target_pattern(&topo64, &prob);
    let bytes = tp.bytes_matrix();
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    let cfg = ta_moe::runtime::ModelCfg {
        p: 64,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f: 4096,
        heads: 16,
        vocab: 50_000,
        batch: 6,
        seq: 1024,
        k: 1,
        cap_factor: 1.0,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: 64,
        capacity: 12_288,
        tokens_per_dev: 6144,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    };
    let counts = converged_counts(&TaMoe { norm: Norm::L1 }, &topo64, &cfg);

    let mut t = Table::new(&["hot path", "mean", "min", "samples"]);
    let mut payload = BTreeMap::new();
    let mut bench = |t: &mut Table, payload: &mut BTreeMap<String, Json>, name: &str, f: &mut dyn FnMut()| {
        let s = time_it(f, warmup, samples);
        t.row(&[
            name.into(),
            format!("{:.1}us", s.mean_us()),
            format!("{:.1}us", s.min_s * 1e6),
            s.iters.to_string(),
        ]);
        payload.insert(name.to_string(), Json::Num(s.mean_us()));
    };

    bench(&mut t, &mut payload, "topology build (cluster_c x8)", &mut || {
        std::hint::black_box(presets::cluster_c(8));
    });
    bench(&mut t, &mut payload, "target_pattern (Eq.7 + repair)", &mut || {
        std::hint::black_box(target_pattern(&topo64, &prob));
    });
    bench(&mut t, &mut payload, "penalty_weights (Eq.8)", &mut || {
        std::hint::black_box(penalty_weights(&tp.c, Norm::L1));
    });
    bench(&mut t, &mut payload, "proportional_caps", &mut || {
        std::hint::black_box(proportional_caps(&tp.c, 12_288));
    });
    {
        // the per-step pricing path: engine constructed once, zero-alloc after
        let mut eng = CostEngine::contention(&topo64);
        bench(&mut t, &mut payload, "contention exchange_time (P=64)", &mut || {
            std::hint::black_box(eng.exchange_time(&bytes));
        });
    }
    bench(&mut t, &mut payload, "step_cost direct (per-step sim)", &mut || {
        std::hint::black_box(step_cost(
            &shape,
            &topo64,
            &counts,
            1,
            device_flops('C'),
            A2aAlgo::Direct,
        ));
    });
    let bvn = A2aAlgo::Scheduled(ScheduleKind::Bvn);
    bench(&mut t, &mut payload, "step_cost sched:bvn (cold)", &mut || {
        std::hint::black_box(step_cost(&shape, &topo64, &counts, 1, device_flops('C'), bvn));
    });
    {
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo64, &counts, 1, device_flops('C'), bvn, &mut cache);
        bench(&mut t, &mut payload, "step_cost sched:bvn (cache hit)", &mut || {
            std::hint::black_box(step_cost_cached(
                &shape,
                &topo64,
                &counts,
                1,
                device_flops('C'),
                bvn,
                &mut cache,
            ));
        });
        assert_eq!(cache.misses(), 1, "warm loop must stay on the hit path");
    }
    bench(&mut t, &mut payload, "bvn_schedule synthesis (P=64)", &mut || {
        std::hint::black_box(bvn_schedule(&topo64, &bytes));
    });

    // asymptotic visibility: the per-step and per-topology paths across P
    for nodes in [2usize, 4, 8, 16] {
        let p = nodes * 8;
        let topo = presets::cluster_c(nodes);
        let sweep_bytes = target_pattern(&topo, &prob).bytes_matrix();
        {
            let mut eng = CostEngine::contention(&topo);
            bench(&mut t, &mut payload, &format!("exchange_time P={p}"), &mut || {
                std::hint::black_box(eng.exchange_time(&sweep_bytes));
            });
        }
        bench(&mut t, &mut payload, &format!("bvn_schedule P={p}"), &mut || {
            std::hint::black_box(bvn_schedule(&topo, &sweep_bytes));
        });
    }

    // sanity: the cached and cold step costs price identically
    {
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        let cold = step_cost(&shape, &topo64, &counts, 1, device_flops('C'), bvn);
        step_cost_cached(&shape, &topo64, &counts, 1, device_flops('C'), bvn, &mut cache);
        let hit =
            step_cost_cached(&shape, &topo64, &counts, 1, device_flops('C'), bvn, &mut cache);
        assert_eq!(hit.a2a_s, cold.a2a_s, "cache hit must reproduce the cold price");
    }

    t.print();
    println!(
        "\nper-step paths (step_cost, exchange_time) must stay far below the\n\
         XLA step wall time (~ms); per-topology paths (bvn_schedule) below 10ms.\n\
         Budgets + history: EXPERIMENTS.md §Perf{}",
        if quick { "  [quick mode]" } else { "" }
    );
    record_jsonl("solver_hotpath", &Json::Obj(payload));
}
