//! L3 hot-path micro-benchmarks (the §Perf baseline): the dispatch solver,
//! penalty construction, the contention cost engine, and the coordinator's
//! per-step host work. These are the pure-rust pieces that run every step
//! or every topology change; the targets and before/after history live in
//! EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo bench --bench solver_hotpath
//! ```

use std::collections::BTreeMap;
use ta_moe::comm::{bvn_schedule, A2aAlgo, CostEngine};
use ta_moe::coordinator::{converged_counts, device_flops, step_cost, ModelShape, TaMoe};
use ta_moe::dispatch::{
    penalty_weights, proportional_caps, target_pattern, DispatchProblem, Norm,
};
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, time_it, Table};
use ta_moe::util::json::Json;

fn main() {
    let topo64 = presets::cluster_c(8); // 64 devices
    let prob = DispatchProblem { k: 1, s: 6144, e_per_dev: 1, elem_bytes: 4096 };
    let tp = target_pattern(&topo64, &prob);
    let bytes = tp.bytes_matrix();
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    let cfg = ta_moe::runtime::ModelCfg {
        p: 64,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f: 4096,
        heads: 16,
        vocab: 50_000,
        batch: 6,
        seq: 1024,
        k: 1,
        cap_factor: 1.0,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: 64,
        capacity: 12_288,
        tokens_per_dev: 6144,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    };
    let counts = converged_counts(&TaMoe { norm: Norm::L1 }, &topo64, &cfg);

    let mut t = Table::new(&["hot path (P=64)", "mean", "min", "samples"]);
    let mut payload = BTreeMap::new();
    let mut bench = |name: &str, f: &mut dyn FnMut()| {
        let s = time_it(f, 3, 20);
        t.row(&[
            name.into(),
            format!("{:.1}us", s.mean_us()),
            format!("{:.1}us", s.min_s * 1e6),
            s.iters.to_string(),
        ]);
        payload.insert(name.to_string(), Json::Num(s.mean_us()));
    };

    bench("topology build (cluster_c x8)", &mut || {
        std::hint::black_box(presets::cluster_c(8));
    });
    bench("target_pattern (Eq.7 + repair)", &mut || {
        std::hint::black_box(target_pattern(&topo64, &prob));
    });
    bench("penalty_weights (Eq.8)", &mut || {
        std::hint::black_box(penalty_weights(&tp.c, Norm::L1));
    });
    bench("proportional_caps", &mut || {
        std::hint::black_box(proportional_caps(&tp.c, 12_288));
    });
    bench("contention exchange_time", &mut || {
        std::hint::black_box(CostEngine::contention(&topo64).exchange_time(&bytes));
    });
    bench("step_cost (per-step sim)", &mut || {
        std::hint::black_box(step_cost(
            &shape,
            &topo64,
            &counts,
            1,
            device_flops('C'),
            A2aAlgo::Direct,
        ));
    });
    bench("bvn_schedule synthesis (P=64)", &mut || {
        std::hint::black_box(bvn_schedule(&topo64, &bytes));
    });
    t.print();
    println!(
        "\nper-step paths (step_cost, exchange_time) must stay far below the\n\
         XLA step wall time (~ms); per-topology paths (solver) below 10ms."
    );
    record_jsonl("solver_hotpath", &Json::Obj(payload));
}
