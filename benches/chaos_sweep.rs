//! Sweep: fault class × dispatch policy under the adaptive vs static stack.
//!
//! Replays each scripted fault class (straggler, degraded link, node loss,
//! gate drift) over the bottlenecked [2,2] tree and reports, per policy,
//! the perturbed-vs-clean simulated clock, the adaptive stack's margin
//! over the static one, and the step-clock recovery time after the fault
//! window closes — the robustness companion to `placement_sweep` /
//! `overlap_sweep`: *how the stack degrades* matters alongside how fast
//! it is when nothing breaks.
//!
//! Shape assertions:
//! * on the even-dispatch arms the adaptive stack (live placement +
//!   epoch-aware plan cache + autotuned overlap) strictly beats the
//!   static stack (canonical hosting, cache pinned, serial clock) under
//!   every fault class;
//! * every bounded fault window yields a finite step-clock recovery.
//!
//! ```bash
//! cargo bench --bench chaos_sweep
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench chaos_sweep   # CI smoke
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::comm::{A2aAlgo, ScheduleKind};
use ta_moe::coordinator::SessionBuilder;
use ta_moe::metrics::RunLog;
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::topology::{Link, Topology, TreeSpec};
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

/// The acceptance fabric: a [2,2] tree whose uplink is the bottleneck, so
/// every fault class has real communication time to stress.
fn bottleneck22() -> Topology {
    Topology::tree(
        &TreeSpec::parse("[2,2]").unwrap(),
        &[Link::from_gbps_us(45.0, 1.0), Link::from_gbps_us(0.01, 1.0)],
        ta_moe::topology::presets::local_copy(),
    )
}

fn run_arm(policy: &str, chaos: &str, adaptive: bool, steps: usize) -> RunLog {
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(bottleneck22())
        .policy_named(policy)
        .a2a(A2aAlgo::Scheduled(ScheduleKind::Bvn))
        .seed(17)
        .chaos_named(chaos);
    b = if adaptive {
        b.placement_every(8).overlap_named("auto")
    } else {
        b.overlap_named("serial").plan_cache_tol(0.0)
    };
    let mut s = b.build().expect("arm builds");
    s.run(steps).expect("arm runs");
    s.log().clone()
}

fn total_s(log: &RunLog) -> f64 {
    log.sim_time_axis().last().copied().unwrap_or(0.0)
}

fn main() {
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let steps = common::env_steps(if quick { 40 } else { 120 });
    let (onset, close) = (steps / 4, steps / 2);

    // every window is bounded and closes mid-run so recovery is observable
    let classes: Vec<(&str, String)> = vec![
        ("straggler", format!("straggler:1x3@{onset}-{close}:flap=4")),
        ("link", format!("link:4x4@{onset}-{close}")),
        ("nodeloss", format!("nodeloss:2@{close}")),
        ("drift", format!("drift:1@{onset}-{close}")),
    ];

    println!("Chaos sweep: fault class × policy, adaptive vs static ({steps} steps)\n");
    let mut t = Table::new(&[
        "policy", "class", "clean", "adaptive", "static", "margin", "recovery", "events",
    ]);
    let mut payload = BTreeMap::new();

    for policy in ["fastmoe", "ta-moe"] {
        let clean_s = total_s(&run_arm(policy, "off", true, steps));
        for (class, spec) in &classes {
            let adaptive = run_arm(policy, spec, true, steps);
            let static_ = run_arm(policy, spec, false, steps);
            let (ta, ts) = (total_s(&adaptive), total_s(&static_));
            let recovery = adaptive.recovery_steps();
            t.row(&[
                policy.into(),
                (*class).into(),
                format!("{:.2}ms", clean_s * 1e3),
                format!("{:.2}ms", ta * 1e3),
                format!("{:.2}ms", ts * 1e3),
                format!("{:+.1}%", (ts - ta) / ts * 100.0),
                recovery.map_or("never".into(), |r| format!("{r} steps")),
                adaptive.perturbations.len().to_string(),
            ]);
            payload.insert(
                format!("{policy}/{class}"),
                Json::Obj(BTreeMap::from([
                    ("clean_s".to_string(), Json::Num(clean_s)),
                    ("adaptive_s".to_string(), Json::Num(ta)),
                    ("static_s".to_string(), Json::Num(ts)),
                    (
                        "recovery_steps".to_string(),
                        Json::Num(recovery.map_or(-1.0, |r| r as f64)),
                    ),
                    (
                        "events".to_string(),
                        Json::Num(adaptive.perturbations.len() as f64),
                    ),
                ])),
            );

            assert!(
                !adaptive.perturbations.is_empty(),
                "{policy}/{class}: the fault stream must reach the run log"
            );
            // bounded window + comm-dominated fabric ⇒ the step clock
            // settles back into the pre-onset band before the run ends
            assert!(
                recovery.is_some(),
                "{policy}/{class}: bounded fault must yield finite recovery"
            );
            // the structural win (proven by the overlap acceptance test on
            // this exact fabric) holds for even dispatch under every class;
            // locality-aware dispatch starves the uplink so its margin is
            // reported but not asserted
            if policy == "fastmoe" {
                assert!(
                    ta < ts,
                    "{policy}/{class}: adaptive clock {ta} must beat static {ts}"
                );
            }
        }
    }
    t.print();
    record_jsonl("chaos_sweep", &Json::Obj(payload));
}
