//! Figure 3 reproduction: validation loss w.r.t. steps — TA-MoE vs the
//! FastMoE baseline must *overlap* (the topology loss does not hurt
//! convergence) across expert scales.
//!
//! Trains the real compiled artifacts on identical synthetic data. Scales
//! here are the CPU-sized 4/8/16-expert worlds standing in for the
//! paper's 8–48 (DESIGN.md §2); the claim under test — curve overlap — is
//! scale-local.
//!
//! ```bash
//! cargo bench --bench fig3_loss_curves            # 120 steps/arm
//! TA_MOE_STEPS=400 cargo bench --bench fig3_loss_curves
//! ```

mod common;

use std::collections::BTreeMap;
use std::path::Path;
use ta_moe::coordinator::{FastMoeEven, TaMoe};
use ta_moe::dispatch::Norm;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn main() -> anyhow::Result<()> {
    let steps = common::env_steps(120);
    let eval_every = 10;
    println!("Figure 3: validation loss vs steps ({steps} steps/arm)\n");

    let mut t = Table::new(&[
        "artifact", "experts", "baseline final ce", "ta-moe final ce", "|delta|", "overlap?",
    ]);
    let mut payload = BTreeMap::new();
    let mut worst: f64 = 0.0;
    for artifact in ["tiny4", "small8_switch", "wide16_switch"] {
        let (base_log, _) =
            common::train_arm(artifact, "C", Box::new(FastMoeEven), steps, 42, eval_every)?;
        let (ta_log, _) = common::train_arm(
            artifact,
            "C",
            Box::new(TaMoe { norm: Norm::L1 }),
            steps,
            42,
            eval_every,
        )?;
        let base_ce = base_log.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
        let ta_ce = ta_log.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
        let delta = (base_ce - ta_ce).abs();
        let rel = delta / base_ce;
        worst = worst.max(rel);
        // experts = P for these single-expert-per-device artifacts
        let experts = match artifact {
            "tiny4" => 4,
            "wide16_switch" => 16,
            _ => 8,
        };
        t.row(&[
            artifact.into(),
            experts.to_string(),
            format!("{base_ce:.4}"),
            format!("{ta_ce:.4}"),
            format!("{delta:.4}"),
            if rel < 0.05 { "yes".into() } else { format!("NO ({:.1}%)", rel * 100.0) },
        ]);

        // dump both curves for plotting
        let dir = Path::new("target/bench-curves");
        base_log.write_csv(&dir.join(format!("fig3_{artifact}_fastmoe.csv")))?;
        ta_log.write_csv(&dir.join(format!("fig3_{artifact}_tamoe.csv")))?;
        payload.insert(format!("{artifact}_base_ce"), Json::Num(base_ce));
        payload.insert(format!("{artifact}_tamoe_ce"), Json::Num(ta_ce));
    }
    t.print();
    println!(
        "\npaper claim: \"the loss curves of TA-MoE and FastMoE are consistent\" — \
         reproduced iff every |delta| is within noise (<5% relative).\n\
         worst relative gap: {:.2}%",
        worst * 100.0
    );
    record_jsonl("fig3_loss_curves", &Json::Obj(payload));
    Ok(())
}
