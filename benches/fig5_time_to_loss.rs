//! Figure 5 reproduction: validation loss w.r.t. *time* vs FasterMoE's
//! Hir gate. The paper's claim: the compulsory ratio converges worse, so
//! TA-MoE reaches fixed loss values 1.25x / 1.47x / 1.54x sooner.
//!
//! Both arms train the same shape on identical data; the time axis is the
//! simulated cluster clock driven by each arm's *measured* dispatch.
//!
//! ```bash
//! cargo bench --bench fig5_time_to_loss
//! TA_MOE_STEPS=400 cargo bench --bench fig5_time_to_loss
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::coordinator::{FasterMoeHir, TaMoe};
use ta_moe::dispatch::Norm;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn main() -> anyhow::Result<()> {
    let steps = common::env_steps(150);
    let eval_every = 5;
    println!("Figure 5: loss vs simulated time, TA-MoE vs FasterMoE-Hir ({steps} steps)\n");

    let (ta_log, _) = common::train_arm(
        "small8_switch",
        "C",
        Box::new(TaMoe { norm: Norm::L1 }),
        steps,
        42,
        eval_every,
    )?;
    let (hir_log, _) = common::train_arm(
        "small8_hir",
        "C",
        Box::new(FasterMoeHir { remote_frac: 0.25 }),
        steps,
        42,
        eval_every,
    )?;

    ta_log.write_csv(std::path::Path::new("target/bench-curves/fig5_tamoe.csv"))?;
    hir_log.write_csv(std::path::Path::new("target/bench-curves/fig5_hir.csv"))?;

    // loss targets: evenly spaced between the common start and the better
    // arm's final loss (the paper picks 3.1/2.9/2.8 for its scale).
    let final_ta = ta_log.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
    let final_hir = hir_log.evals.last().map(|e| e.1).unwrap_or(f64::NAN);
    let first = ta_log.evals.first().map(|e| e.1).unwrap_or(f64::NAN);
    let best = final_ta.min(final_hir);
    let targets: Vec<f64> = (1..=3)
        .map(|i| first - (first - best) * (0.5 + 0.15 * i as f64))
        .collect();

    let mut t = Table::new(&["target ce", "TA-MoE time", "FasterMoE time", "time ratio"]);
    let mut payload = BTreeMap::new();
    for (i, &tg) in targets.iter().enumerate() {
        let ta = ta_log.sim_time_to_loss(tg);
        let hir = hir_log.sim_time_to_loss(tg);
        let row = match (ta, hir) {
            (Some(a), Some(h)) => {
                payload.insert(format!("speedup_{i}"), Json::Num(h / a));
                [format!("{tg:.3}"), format!("{a:.3}s"), format!("{h:.3}s"),
                 format!("{:.2}x", h / a)]
            }
            (Some(a), None) => [format!("{tg:.3}"), format!("{a:.3}s"),
                                "not reached".into(), "inf".into()],
            (None, _) => [format!("{tg:.3}"), "not reached".into(), "-".into(), "-".into()],
        };
        t.row(&row);
    }
    t.print();
    println!(
        "\nfinal valid ce: TA-MoE {final_ta:.4}, FasterMoE-Hir {final_hir:.4} \
         (paper: Hir converges worse; time-to-loss speedups 1.25x/1.47x/1.54x)"
    );
    // What is reproducible at this step budget is the *mechanism*: the
    // compulsory ratio hurts convergence (final CE ordering). The paper's
    // full time-axis win additionally needs the TA-MoE gate to have
    // converged onto c-hat (a 10^5-step horizon); at ~150 steps the
    // dispatch has barely shifted, so we assert the convergence ordering
    // and report the time table for the record (EXPERIMENTS.md §Fig5).
    assert!(
        final_ta < final_hir,
        "compulsory-ratio gate should converge worse: TA {final_ta} vs Hir {final_hir}"
    );
    payload.insert("final_ta_ce".into(), Json::Num(final_ta));
    payload.insert("final_hir_ce".into(), Json::Num(final_hir));
    record_jsonl("fig5_time_to_loss", &Json::Obj(payload));
    Ok(())
}
