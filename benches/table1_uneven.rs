//! Table 1 reproduction: even vs uneven dispatch on the [[0,1],[0̂,1̂]]
//! topology, 128 MB per rank (paper §3.3, the motivation experiment).
//!
//! Paper rows (µs):  even  144 / 758 / 5609 / 5618 | All 14019
//!                 uneven  144 / 1492 / 2835 / 2861 | All 10765
//!
//! ```bash
//! cargo bench --bench table1_uneven
//! ```

use std::collections::BTreeMap;
use ta_moe::comm::profile_exchange;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;
use ta_moe::util::Mat;

fn main() {
    let topo = presets::table1();
    let bytes = 128.0 * 1024.0 * 1024.0;
    let even = Mat::filled(4, 4, 0.25);
    let peer = [1usize, 0, 3, 2];
    let uneven = Mat::from_fn(4, 4, |i, j| {
        if i == j {
            0.25
        } else if j == peer[i] {
            0.5
        } else {
            0.125
        }
    });

    println!("Table 1: communication on [[0,1],[0',1']], 128 MB per rank\n");
    let mut t = Table::new(&[
        "pattern", "ratio", "0<->0", "0<->1", "0<->0'", "0<->1'", "All (us)",
    ]);
    let mut totals = Vec::new();
    for (name, ratio_str, ratios) in [
        ("even", "1/4,1/4,1/4,1/4", &even),
        ("uneven", "1/4,1/2,1/8,1/8", &uneven),
    ] {
        let p = profile_exchange(&topo, bytes, ratios);
        let us: Vec<f64> = p.rank0_times.iter().map(|s| s * 1e6).collect();
        t.row(&[
            name.into(),
            ratio_str.into(),
            format!("{:.0}", us[0]),
            format!("{:.0}", us[1]),
            format!("{:.0}", us[2]),
            format!("{:.0}", us[3]),
            format!("{:.0}", p.rank0_total * 1e6),
        ]);
        totals.push((name, p.rank0_total));
    }
    t.print();
    let speedup = totals[0].1 / totals[1].1;
    println!(
        "\nuneven/even improvement: {:.2}x (paper: {:.2}x)",
        speedup,
        14019.0 / 10765.0
    );
    assert!(speedup > 1.15, "uneven must beat even — got {speedup}");

    let mut m = BTreeMap::new();
    m.insert("even_total_us".into(), Json::Num(totals[0].1 * 1e6));
    m.insert("uneven_total_us".into(), Json::Num(totals[1].1 * 1e6));
    m.insert("speedup".into(), Json::Num(speedup));
    record_jsonl("table1_uneven", &Json::Obj(m));
}
