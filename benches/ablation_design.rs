//! Ablations over the design choices DESIGN.md calls out (not a paper
//! figure — supporting evidence for §4 design decisions):
//!
//! 1. **Normalisation of Eq. 8** — plain L1 vs softmax sharpening: how the
//!    penalty ratio and the induced comm time differ.
//! 2. **Exchange model** — slowest-pair bound vs scheduled rounds (xor /
//!    rotation) vs fully-concurrent contention vs per-sender serial: where
//!    the Eq. 2 lower bound sits relative to realistic schedules.
//! 3. **Hierarchical vs direct all-to-all** under even and TA-MoE
//!    dispatch: the system-level optimisation the related work uses, and
//!    why it is orthogonal to the dispatch pattern.
//! 4. **Asymmetric merge on/off** — expert isolation on [[2,2],[2]]-style
//!    topologies (the §4.2 guard).
//!
//! ```bash
//! cargo bench --bench ablation_design
//! ```

use ta_moe::comm::{
    bvn_schedule, hierarchical_a2a_time, rotation_schedule, scheduled_a2a_time,
    xor_schedule, CostEngine,
};
use ta_moe::dispatch::{penalty_weights, target_pattern, DispatchProblem, Norm};
use ta_moe::topology::presets;
use ta_moe::util::bench::{fmt_time, Table};
use ta_moe::util::Mat;

fn main() {
    let prob = DispatchProblem { k: 1, s: 6144, e_per_dev: 1, elem_bytes: 4096 };

    // --- 1. Eq.8 normalisation ---------------------------------------------
    println!("== ablation: penalty normalisation (cluster C × 2 nodes) ==");
    let topo = presets::cluster_c(2);
    let tp = target_pattern(&topo, &prob);
    let mut t = Table::new(&["norm", "min p_0e", "max p_0e", "max/min"]);
    for (name, norm) in [
        ("L1", Norm::L1),
        ("softmax t=2", Norm::Softmax { temp: 2.0 }),
        ("softmax t=4", Norm::Softmax { temp: 4.0 }),
    ] {
        let w = penalty_weights(&tp.c, norm);
        let row = w.row(0);
        let mn = row.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = row.iter().cloned().fold(0.0f64, f64::max);
        t.row(&[
            name.into(),
            format!("{mn:.4}"),
            format!("{mx:.4}"),
            format!("{:.1}", mx / mn),
        ]);
    }
    t.print();
    println!("(softmax sharpens the low-bandwidth penalty, as §4.3 suggests)\n");

    // --- 2. exchange models --------------------------------------------------
    println!("== ablation: exchange models (even dispatch, 2-node cluster C) ==");
    let p = topo.p();
    let bytes = Mat::filled(p, p, (prob.s * prob.elem_bytes) as f64 / p as f64);
    let mut t = Table::new(&["model", "time", "vs bound"]);
    let bound = CostEngine::slowest_pair(&topo).exchange_time(&bytes);
    for (name, time) in [
        ("slowest-pair (Eq.2 bound)", bound),
        ("concurrent + contention", CostEngine::contention(&topo).exchange_time(&bytes)),
        ("xor rounds", scheduled_a2a_time(&topo, &bytes, &xor_schedule(p))),
        ("rotation rounds", scheduled_a2a_time(&topo, &bytes, &rotation_schedule(p))),
        ("bvn rounds (byte-aware)", scheduled_a2a_time(&topo, &bytes, &bvn_schedule(&topo, &bytes))),
        ("per-sender serial", CostEngine::per_sender(&topo).exchange_time(&bytes)),
    ] {
        t.row(&[name.into(), fmt_time(time), format!("{:.2}x", time / bound)]);
    }
    t.print();
    println!("(\"most implementations approach the lower bound\" — §4.1; the rounds sit between)\n");

    // --- 3. hierarchical vs direct under both dispatches ---------------------
    println!("== ablation: hierarchical a2a × dispatch pattern (4-node cluster C) ==");
    let topo4 = presets::cluster_c(4);
    let p4 = topo4.p();
    let prob4 = DispatchProblem { elem_bytes: 2048, ..prob };
    let tp4 = target_pattern(&topo4, &prob4);
    let even4 = Mat::filled(p4, p4, (prob4.s * prob4.elem_bytes) as f64 / p4 as f64);
    let ta4 = tp4.bytes_matrix();
    let mut t = Table::new(&["dispatch", "direct", "hierarchical", "hier gain"]);
    for (name, b) in [("even", &even4), ("TA-MoE target", &ta4)] {
        let direct = CostEngine::contention(&topo4).exchange_time(b);
        let hier = hierarchical_a2a_time(&topo4, b).total();
        t.row(&[
            name.into(),
            fmt_time(direct),
            fmt_time(hier),
            format!("{:.2}x", direct / hier),
        ]);
    }
    t.print();
    println!("(topology-aware dispatch helps with either kernel — orthogonal optimisations)\n");

    // --- 4. asymmetric merge guard -------------------------------------------
    println!("== ablation: asymmetric merge ([[2,2],[2]], §4.2 expert isolation) ==");
    use ta_moe::topology::{Link, Topology, TreeSpec};
    let spec = TreeSpec::parse("[[2,2],[2]]").unwrap();
    let atopo = Topology::tree(
        &spec,
        &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(12.5, 10.0)],
        presets::local_copy(),
    );
    let tp = target_pattern(&atopo, &prob);
    // with the merge, cross-node volumes are uniform per sender: report the
    // spread that would signal isolation
    let mut worst_ratio: f64 = 1.0;
    for i in 0..atopo.p() {
        let cross: Vec<f64> = (0..atopo.p())
            .filter(|&e| !atopo.same_node(i, e))
            .map(|e| tp.c.get(i, e))
            .collect();
        let mn = cross.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = cross.iter().cloned().fold(0.0f64, f64::max);
        worst_ratio = worst_ratio.max(mx / mn);
    }
    println!(
        "worst cross-node volume spread after merge: {worst_ratio:.2}x \
         (≤1.5x ⇒ no expert isolation)\n"
    );
    assert!(worst_ratio < 1.5, "merge failed to prevent expert isolation");
}
