//! Table 4 (appendix) reproduction: validation perplexity of TA-MoE vs
//! the FastMoE baseline at fixed step budget across expert scales — the
//! convergence-neutrality claim in PPL form (paper: 17.97 vs 18.12 at 8
//! experts etc.; TA-MoE within ±1% of baseline everywhere).
//!
//! ```bash
//! cargo bench --bench table4_ppl
//! TA_MOE_STEPS=400 cargo bench --bench table4_ppl
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::coordinator::{FastMoeEven, TaMoe};
use ta_moe::dispatch::Norm;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn main() -> anyhow::Result<()> {
    let steps = common::env_steps(150);
    println!("Table 4: validation PPL at {steps} steps (byte-level)\n");

    let mut t = Table::new(&["experts", "TA-MoE PPL", "baseline PPL", "ratio"]);
    let mut payload = BTreeMap::new();
    for (artifact, experts) in [("tiny4", 4usize), ("small8_switch", 8), ("wide16_switch", 16)] {
        let (base, _) =
            common::train_arm(artifact, "C", Box::new(FastMoeEven), steps, 42, steps)?;
        let (ta, _) = common::train_arm(
            artifact,
            "C",
            Box::new(TaMoe { norm: Norm::L1 }),
            steps,
            42,
            steps,
        )?;
        let base_ppl = base.evals.last().map(|e| e.1.exp()).unwrap_or(f64::NAN);
        let ta_ppl = ta.evals.last().map(|e| e.1.exp()).unwrap_or(f64::NAN);
        let ratio = ta_ppl / base_ppl;
        payload.insert(format!("ppl_ratio_{experts}"), Json::Num(ratio));
        t.row(&[
            experts.to_string(),
            format!("{ta_ppl:.2}"),
            format!("{base_ppl:.2}"),
            format!("{ratio:.3}"),
        ]);
        assert!(
            (0.90..1.10).contains(&ratio),
            "PPL ratio at {experts} experts out of band: {ratio}"
        );
    }
    t.print();
    println!(
        "\npaper claim: TA-MoE PPL tracks the baseline (ratios 0.99–1.01 at 10w steps);\n\
         at this short budget we accept ±10% and check no systematic regression."
    );
    record_jsonl("table4_ppl", &Json::Obj(payload));
    Ok(())
}
