//! Sweep: expert placement on/off × dispatch policy × cluster.
//!
//! Trains sim sessions with the placement engine enabled vs the canonical
//! hosting and reports total a2a time, migrations, weight bytes moved, and
//! predicted-vs-realized per-step savings — the placement-layer companion
//! to `ablation_a2a`: *where the experts live* matters alongside what the
//! pattern is and how it executes on the wire.
//!
//! Shape assertions:
//! * with an amortisation-gated engine, placement-on never loses more
//!   than fp noise vs canonical on any arm (migrations only trigger on
//!   predicted wins);
//! * on the skewed-load arm over the [2,2] tree, placement-on strictly
//!   reduces total a2a time and performs at least one migration.
//!
//! ```bash
//! cargo bench --bench placement_sweep
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench placement_sweep   # CI smoke
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::coordinator::{
    device_flops, DispatchPolicy, FastMoeEven, PolicyInputs, SessionBuilder, TaMoe,
};
use ta_moe::dispatch::{even_caps, Norm};
use ta_moe::metrics::RunLog;
use ta_moe::runtime::{GateInputs, ModelCfg, SimBackend};
use ta_moe::topology::{presets, Topology};
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;
use ta_moe::util::Mat;

/// The acceptance-scenario load: node-0 devices crowd the experts
/// canonically hosted off-node, node-1 devices dispatch uniformly
/// (mirrors the `session_sim` placement test).
#[derive(Debug)]
struct SkewedLoad;

impl DispatchPolicy for SkewedLoad {
    fn name(&self) -> String {
        "skewed-load".into()
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        let penalty = Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            if topo.node_of(i) == 0 && topo.node_of(e / cfg.e_per_dev) == 0 {
                9.0
            } else {
                1.0
            }
        });
        PolicyInputs {
            gate: GateInputs {
                penalty,
                caps: even_caps(cfg.p, cfg.n_experts, cfg.capacity),
                local_mask: topo.local_mask(cfg.n_experts, cfg.e_per_dev),
                hir_remote_frac: 1.0,
            },
            target: None,
        }
    }

    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
        let inputs = self.runtime_inputs(topo, cfg);
        let sent = (cfg.k * cfg.tokens_per_dev) as f64;
        Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            let w = 1.0 / inputs.gate.penalty.get(i, e);
            let row: f64 =
                (0..cfg.n_experts).map(|x| 1.0 / inputs.gate.penalty.get(i, x)).sum();
            sent * w / row
        })
    }
}

fn policy_for(name: &str) -> Box<dyn DispatchPolicy> {
    match name {
        "fastmoe" => Box::new(FastMoeEven),
        "ta-moe" => Box::new(TaMoe { norm: Norm::L1 }),
        _ => Box::new(SkewedLoad),
    }
}

fn run_arm(
    preset: &str,
    topo: Topology,
    policy: &str,
    steps: usize,
    placement_every: usize,
) -> RunLog {
    let cfg = ModelCfg::preset(preset).expect("builtin preset");
    let mut s = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(topo)
        .policy(policy_for(policy))
        .seed(33)
        .flops_per_dev(device_flops('C'))
        .placement_every(placement_every)
        .build()
        .expect("arm builds");
    s.run(steps).expect("arm trains");
    s.log().clone()
}

fn a2a_total(log: &RunLog) -> f64 {
    let (l, a, e) = log.a2a_phase_totals();
    l + a + e
}

fn main() {
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let steps = common::env_steps(if quick { 60 } else { 200 });
    let every = 8;

    println!("Placement sweep: placement on/off × policy × cluster ({steps} steps)\n");
    let mut t = Table::new(&[
        "cluster", "policy", "a2a off", "a2a on", "saving", "migrations", "KiB moved",
        "pred/real ms-step",
    ]);
    let mut payload = BTreeMap::new();

    let arms: Vec<(&str, &str, Topology, &str)> = vec![
        ("table1", "tiny4", presets::table1(), "skewed-load"),
        ("table1", "tiny4", presets::table1(), "fastmoe"),
        ("C×2", "wide16_switch", presets::cluster_c(2), "ta-moe"),
        ("C×2", "wide16_switch", presets::cluster_c(2), "fastmoe"),
    ];
    for (cluster, preset, topo, policy) in arms {
        let off = run_arm(preset, topo.clone(), policy, steps, 0);
        let on = run_arm(preset, topo, policy, steps, every);
        let (t_off, t_on) = (a2a_total(&off), a2a_total(&on));
        let (pred, real) = on.migration_savings();
        t.row(&[
            cluster.into(),
            policy.into(),
            format!("{:.2}ms", t_off * 1e3),
            format!("{:.2}ms", t_on * 1e3),
            format!("{:+.1}%", (t_off - t_on) / t_off * 100.0),
            on.migrations.len().to_string(),
            format!("{:.0}", on.migration_bytes() / 1024.0),
            format!("{:.4}/{:.4}", pred * 1e3, real * 1e3),
        ]);
        payload.insert(
            format!("{cluster}/{policy}"),
            Json::Obj(BTreeMap::from([
                ("a2a_off_s".to_string(), Json::Num(t_off)),
                ("a2a_on_s".to_string(), Json::Num(t_on)),
                ("migrations".to_string(), Json::Num(on.migrations.len() as f64)),
                ("migration_bytes".to_string(), Json::Num(on.migration_bytes())),
            ])),
        );

        // the amortisation gate guarantees a *predicted* win on the EWMA
        // loads, not a realized one — the 5% slack absorbs bounded
        // transient misprediction, which is the actual worst case
        assert!(
            t_on <= t_off * 1.05,
            "{cluster}/{policy}: placement-on a2a {t_on} worse than off {t_off}"
        );
        // the hard invariant: every accepted migration predicted a win
        assert!(
            on.migrations.iter().all(|m| m.predicted_saving_s > 0.0),
            "{cluster}/{policy}: a migration was accepted without a predicted win"
        );
        if policy == "skewed-load" {
            assert!(
                t_on < t_off && !on.migrations.is_empty(),
                "{cluster}/{policy}: skewed arm must migrate and win ({t_on} vs {t_off})"
            );
        }
    }
    t.print();
    record_jsonl("placement_sweep", &Json::Obj(payload));
}
