//! Ablation: a2a execution plan × dispatch policy × cluster.
//!
//! For every cluster preset and dispatch policy under comparison, price one
//! training step under each [`A2aAlgo`] (`direct`, `hier`, `sched:xor`,
//! `sched:rot`, `sched:bvn`) and report the a2a share plus its per-phase
//! split — the planner-level companion to fig4: *how* the pattern is
//! executed on the wire matters as much as *what* the pattern is.
//!
//! Shape assertions:
//! * `sched:bvn` never prices above `sched:rot` (the synthesizer's
//!   guarantee), on every cluster × policy arm;
//! * every algo stays above the Eq. 2 slowest-pair lower bound;
//! * TA-MoE dispatch beats even dispatch under *every* algo on cluster C —
//!   topology-aware dispatch and wire scheduling compose.
//!
//! ```bash
//! cargo bench --bench ablation_a2a
//! TA_MOE_BENCH_QUICK=1 cargo bench --bench ablation_a2a   # CI smoke
//! ```
//!
//! Quick mode keeps every shape assertion but sweeps only the 2-node
//! cluster-C arm (the one the paper's headline numbers come from).

use std::collections::BTreeMap;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, step_cost, DeepSpeedEven, DispatchPolicy,
    FastMoeEven, FasterMoeHir, ModelShape, TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn cfg_for(p: usize) -> ModelCfg {
    ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f: 4096,
        heads: 16,
        vocab: 50_000,
        batch: 6,
        seq: 1024,
        k: 1,
        cap_factor: 1.0,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: p,
        capacity: 12_288,
        tokens_per_dev: 6144,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}

fn policies() -> Vec<Box<dyn DispatchPolicy>> {
    vec![
        Box::new(FastMoeEven),
        Box::new(DeepSpeedEven),
        Box::new(FasterMoeHir { remote_frac: 0.25 }),
        Box::new(TaMoe { norm: Norm::L1 }),
    ]
}

fn main() {
    // CI quick mode: one cluster arm, every assertion still enforced
    let quick = std::env::var("TA_MOE_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    println!("Ablation: a2a plan × dispatch policy × cluster (per-step a2a seconds)\n");
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    let mut payload = BTreeMap::new();

    let arms: &[(&str, usize)] =
        if quick { &[("C", 2)] } else { &[("B", 2), ("C", 2), ("C", 4)] };
    for &(cluster, nodes) in arms {
        let topo = presets::by_name(cluster, nodes).unwrap();
        let p = topo.p();
        let cfg = cfg_for(p);
        let flops = device_flops(cluster.chars().next().unwrap());
        println!("== cluster {cluster} × {nodes} nodes (P={p}) ==");
        let mut t = Table::new(&[
            "policy", "direct", "hier", "sched:xor", "sched:rot", "sched:bvn",
            "bvn intra/inter",
        ]);
        for policy in policies() {
            let counts = converged_counts(policy.as_ref(), &topo, &cfg);
            let mut cells = vec![policy.name()];
            let mut by_algo = BTreeMap::new();
            for algo in A2aAlgo::ALL {
                if algo.validate_for(p).is_err() {
                    cells.push("n/a".into());
                    continue;
                }
                let cost = step_cost(&shape, &topo, &counts, 1, flops, algo);
                by_algo.insert(algo.name(), cost);
                cells.push(format!("{:.1}ms", cost.a2a_s * 1e3));
            }
            let bvn = by_algo["sched:bvn"];
            let rot = by_algo["sched:rot"];
            cells.push(format!(
                "{:.1}/{:.1}ms",
                bvn.a2a.intra_s * 1e3,
                bvn.a2a.inter_s * 1e3
            ));
            t.row(&cells);

            // the synthesizer's guarantee: never worse than rotation
            assert!(
                bvn.a2a_s <= rot.a2a_s * (1.0 + 1e-9),
                "{cluster}x{nodes}/{}: bvn {} > rot {}",
                policy.name(),
                bvn.a2a_s,
                rot.a2a_s
            );
            payload.insert(
                format!("{cluster}{nodes}_{}_bvn_vs_rot", policy.name()),
                Json::Num(bvn.a2a_s / rot.a2a_s),
            );
        }
        t.print();
        println!();
    }

    // dispatch pattern × wire plan compose: TA-MoE wins under every algo
    let topo = presets::cluster_c(2);
    let cfg = cfg_for(topo.p());
    let flops = device_flops('C');
    let even = converged_counts(&FastMoeEven, &topo, &cfg);
    let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
    for algo in A2aAlgo::ALL {
        let c_even = step_cost(&shape, &topo, &even, 1, flops, algo);
        let c_ta = step_cost(&shape, &topo, &ta, 1, flops, algo);
        assert!(
            c_ta.a2a_s < c_even.a2a_s,
            "{algo}: TA-MoE a2a {} !< even {}",
            c_ta.a2a_s,
            c_even.a2a_s
        );
        payload.insert(
            format!("compose_speedup_{}", algo.name()),
            Json::Num(c_even.a2a_s / c_ta.a2a_s),
        );
    }
    println!(
        "TA-MoE's dispatch pattern beats even dispatch under every wire plan —\n\
         topology-aware dispatch and round scheduling are composable wins."
    );
    record_jsonl("ablation_a2a", &Json::Obj(payload));
}
