//! Figure 7 (appendix) reproduction: dispatch distributions across expert
//! scales, TA-MoE vs the even FastMoE baseline.
//!
//! The paper's observations to reproduce:
//! * single-node scales: topology influence is small (intra-node bandwidth
//!   variance is small) — distributions stay near-uniform;
//! * multi-node scales: a "ladder" — ranks prefer intra-node experts,
//!   while the FastMoE baseline stays flat.
//!
//! ```bash
//! cargo bench --bench fig7_dispatch
//! ```

mod common;

use std::collections::BTreeMap;
use ta_moe::config::topology_for;
use ta_moe::coordinator::{DispatchPolicy, FastMoeEven, TaMoe};
use ta_moe::dispatch::Norm;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;
use ta_moe::util::Mat;

fn on_node_frac(counts: &Mat, topo: &ta_moe::topology::Topology, rank: usize) -> f64 {
    let row = counts.row(rank);
    let on: f64 = row
        .iter()
        .enumerate()
        .filter(|(e, _)| topo.same_node(rank, *e))
        .map(|(_, v)| v)
        .sum();
    on / row.iter().sum::<f64>()
}

fn main() -> anyhow::Result<()> {
    let steps = common::env_steps(120);
    println!("Figure 7: rank-0 dispatch distributions after {steps} steps\n");

    let mut payload = BTreeMap::new();
    let mut t = Table::new(&[
        "artifact", "nodes", "arm", "rank0 row (tokens -> expert)", "on-node %",
    ]);
    for artifact in ["tiny4", "small8_switch", "wide16_switch"] {
        let p = match artifact {
            "tiny4" => 4,
            "wide16_switch" => 16,
            _ => 8,
        };
        let topo = topology_for("C", p);
        let arms: [(&str, Box<dyn DispatchPolicy>); 2] = [
            ("fastmoe", Box::new(FastMoeEven)),
            ("ta-moe", Box::new(TaMoe { norm: Norm::L1 })),
        ];
        for (arm, policy) in arms {
            let (_, counts) = common::train_arm(artifact, "C", policy, steps, 42, 0)?;
            let frac = on_node_frac(&counts, &topo, 0);
            let row: Vec<String> = counts
                .row(0)
                .iter()
                .take(8)
                .map(|v| format!("{v:.0}"))
                .collect();
            t.row(&[
                artifact.into(),
                topo.n_nodes().to_string(),
                arm.into(),
                row.join(" "),
                format!("{:.0}%", frac * 100.0),
            ]);
            payload.insert(format!("{artifact}_{arm}_onnode"), Json::Num(frac));
        }
    }
    t.print();

    // Ladder assertion on the largest multi-node scale: TA-MoE's on-node
    // share must exceed the baseline's.
    let ta = payload["wide16_switch_ta-moe_onnode"].as_f64().unwrap();
    let base = payload["wide16_switch_fastmoe_onnode"].as_f64().unwrap();
    println!(
        "\nladder check @16 experts: TA-MoE on-node {:.0}% vs baseline {:.0}% \
         (paper: \"high preference to dispatch the data to intra-node rank group\")",
        ta * 100.0,
        base * 100.0
    );
    assert!(
        ta > base,
        "TA-MoE on-node share ({ta:.2}) must exceed the even baseline ({base:.2})"
    );
    record_jsonl("fig7_dispatch", &Json::Obj(payload));
    Ok(())
}
