//! Shared helpers for the paper-reproduction benches.
//!
//! Every bench is a `harness = false` main that prints the same rows or
//! series its paper table/figure reports and appends a JSON record to
//! `target/bench-results.jsonl` (see `util::bench::record_jsonl`).
//!
//! Training arms go through the `Session` builder with backend `auto`:
//! compiled artifacts under `--features backend-xla` when present, the
//! pure-rust simulator otherwise — so `cargo bench` works on a fresh
//! clone with no XLA.

// each bench target compiles this module and uses a subset of the helpers
#![allow(dead_code)]

use anyhow::Result;
use ta_moe::coordinator::{device_flops, DispatchPolicy, SessionBuilder};
use ta_moe::metrics::RunLog;

/// Env-tunable step budget so `cargo bench` stays tractable on 1 CPU but a
/// longer run can be requested (`TA_MOE_STEPS=400 cargo bench ...`).
pub fn env_steps(default: usize) -> usize {
    std::env::var("TA_MOE_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Train one arm: artifact × policy × cluster, identical data per seed.
/// Returns the run log (loss curve on the simulated clock) and the final
/// dispatch counts.
pub fn train_arm(
    artifact: &str,
    cluster: &str,
    policy: Box<dyn DispatchPolicy>,
    steps: usize,
    seed: u64,
    eval_every: usize,
) -> Result<(RunLog, ta_moe::util::Mat)> {
    let cluster_char = cluster.chars().next().unwrap_or('C');
    let mut session = SessionBuilder::new()
        .artifact("artifacts", artifact)
        .cluster(cluster)
        .policy(policy)
        .lr(1e-3)
        .seed(seed as i32)
        .flops_per_dev(device_flops(cluster_char))
        .data_synthetic(seed)
        .eval_every(eval_every)
        .build()?;
    session.run(steps)?;
    let counts = session.last_counts().cloned().expect("at least one step");
    Ok((session.log().clone(), counts))
}
