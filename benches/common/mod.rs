//! Shared helpers for the paper-reproduction benches.
//!
//! Every bench is a `harness = false` main that prints the same rows or
//! series its paper table/figure reports and appends a JSON record to
//! `target/bench-results.jsonl` (see `util::bench::record_jsonl`).

use anyhow::Result;
use std::path::Path;
use ta_moe::config::topology_for;
use ta_moe::coordinator::{device_flops, Strategy, Trainer, TrainerOptions};
use ta_moe::data::{Batcher, SyntheticCorpus};
use ta_moe::metrics::RunLog;

/// Env-tunable step budget so `cargo bench` stays tractable on 1 CPU but a
/// longer run can be requested (`TA_MOE_STEPS=400 cargo bench ...`).
pub fn env_steps(default: usize) -> usize {
    std::env::var("TA_MOE_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Train one arm: artifact × strategy × cluster, identical data per seed.
/// Returns the run log (loss curve on the simulated clock).
pub fn train_arm(
    artifact: &str,
    cluster: &str,
    strategy: Strategy,
    steps: usize,
    seed: u64,
    eval_every: usize,
) -> Result<(RunLog, ta_moe::util::Mat)> {
    let dir = format!("artifacts/{artifact}");
    let manifest = ta_moe::runtime::Manifest::load(Path::new(&dir))?;
    let topo = topology_for(cluster, manifest.config.p);
    let cluster_char = cluster.chars().next().unwrap_or('C');
    let mut trainer = Trainer::new(
        Path::new(&dir),
        topo,
        strategy,
        TrainerOptions { lr: 1e-3, seed: seed as i32, flops_per_dev: device_flops(cluster_char) },
    )?;
    let cfg = trainer.manifest().config.clone();

    let mut corpus = SyntheticCorpus::new(seed);
    let stream = corpus.tokens(cfg.p * cfg.batch * (cfg.seq + 1) * 128);
    let mut batcher = Batcher::new(stream, cfg.p, cfg.batch, cfg.seq);
    let mut vcorpus = SyntheticCorpus::new(seed + 999);
    let vstream = vcorpus.tokens(cfg.p * cfg.batch * (cfg.seq + 1) * 8);
    let (vtok, vtgt) = Batcher::new(vstream, cfg.p, cfg.batch, cfg.seq).next_batch();

    let mut last_counts = None;
    for step in 0..steps {
        let (tok, tgt) = batcher.next_batch();
        trainer.train_step(&tok, &tgt)?;
        if eval_every > 0 && (step + 1) % eval_every == 0 {
            trainer.eval(&vtok, &vtgt)?;
        }
        last_counts = trainer.last_counts().cloned();
    }
    Ok((
        trainer.log().clone(),
        last_counts.expect("at least one step"),
    ))
}
