//! Figure 8 (appendix) reproduction: TA-MoE speedup over FastMoE on a
//! Swin-Transformer-shaped MoE, cluster A at 16 and 32 GPUs
//! (paper: 1.18x and 1.20x).
//!
//! ```bash
//! cargo bench --bench fig8_swin
//! ```

use std::collections::BTreeMap;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, throughput, FastMoeEven, ModelShape, TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn swin_cfg(p: usize) -> ModelCfg {
    let tokens = 2 * 49 * 32; // 2 images × 32 windows × 49 patches
    ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 384,
        f: 1536,
        heads: 12,
        vocab: 1000,
        batch: 2,
        seq: tokens / 2,
        k: 2, // GShard gate (Table 5)
        cap_factor: 1.2,
        gate: "gshard".into(),
        dispatch: "local".into(),
        n_experts: p,
        capacity: tokens * 2,
        tokens_per_dev: tokens,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}

fn swin_shape(tokens: usize) -> ModelShape {
    ModelShape {
        layers: 12,
        d: 384,
        f: 1536,
        vocab: 1000,
        seq: 49,
        tokens_per_dev: tokens,
        k: 2,
        n_moe_layers: 6,
        elem_bytes: 2,
    }
}

fn main() {
    println!("Figure 8: Swin-MoE speedup over FastMoE on cluster A\n");
    let mut t = Table::new(&["GPUs", "topology", "FastMoE tok/s", "TA-MoE tok/s", "speedup"]);
    let mut payload = BTreeMap::new();
    let mut speeds = Vec::new();
    for (gpus, nodes) in [(16usize, 2usize), (32, 4)] {
        let topo = presets::cluster_a(nodes);
        let cfg = swin_cfg(gpus);
        let shape = swin_shape(cfg.tokens_per_dev);
        let flops = device_flops('A');
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let thr_even = throughput(&shape, &topo, &even, 1, flops, A2aAlgo::Direct);
        let thr_ta = throughput(&shape, &topo, &ta, 1, flops, A2aAlgo::Direct);
        let s = thr_ta / thr_even;
        speeds.push(s);
        payload.insert(format!("speedup_{gpus}"), Json::Num(s));
        t.row(&[
            gpus.to_string(),
            if nodes == 2 { "symmetric" } else { "asymmetric" }.into(),
            format!("{thr_even:.0}"),
            format!("{thr_ta:.0}"),
            format!("{s:.2}x"),
        ]);
    }
    t.print();
    println!("\npaper: 1.18x @16 GPUs, 1.20x @32 GPUs");
    for s in &speeds {
        assert!(*s > 1.0, "TA-MoE should win on the vision workload too: {s}");
    }
    record_jsonl("fig8_swin", &Json::Obj(payload));
}
