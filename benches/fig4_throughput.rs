//! Figure 4 reproduction: throughput (tokens/s) and TA-MoE speedup over
//! DeepSpeed-MoE and FastMoE across clusters A/B/C, Switch/GShard gates,
//! and expert scales — on the simulated cluster clock at GPT-Medium scale
//! (paper Table 3 shapes; absolute numbers are the cost model's, the
//! *shape* — who wins, by how much, where — is the reproduction target).
//!
//! ```bash
//! cargo bench --bench fig4_throughput
//! ```

use std::collections::BTreeMap;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, throughput, DeepSpeedEven, FastMoeEven, ModelShape,
    TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::presets;
use ta_moe::util::bench::{record_jsonl, Table};
use ta_moe::util::json::Json;

fn cfg_for(p: usize, gshard: bool) -> ModelCfg {
    let (k, f, batch, seq) = if gshard { (2, 2048, 6, 1024) } else { (1, 4096, 6, 1024) };
    ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 1024,
        f,
        heads: 16,
        vocab: 50_000,
        batch,
        seq,
        k,
        cap_factor: if gshard { 2.0 } else { 1.0 },
        gate: if gshard { "gshard".into() } else { "switch".into() },
        dispatch: "local".into(),
        n_experts: p,
        capacity: batch * seq * k * 2,
        tokens_per_dev: batch * seq,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}

fn main() {
    println!("Figure 4: throughput and speedups at GPT-Medium scale (simulated clock)\n");
    let mut results = Vec::new();
    for (cluster, scales) in [
        ('A', vec![8usize, 16, 32, 64]),
        ('B', vec![8, 16, 32]),
        ('C', vec![8, 16, 32, 64]),
    ] {
        for gshard in [false, true] {
            let gate = if gshard { "GShard" } else { "Switch" };
            println!("== cluster {cluster} / {gate} gate ==");
            let mut t = Table::new(&[
                "experts", "DeepSpeed tok/s", "FastMoE tok/s", "TA-MoE tok/s",
                "vs DS", "vs FastMoE",
            ]);
            for &p in &scales {
                let topo = presets::by_name(&cluster.to_string(), p / 8).unwrap();
                let cfg = cfg_for(p, gshard);
                let shape = ModelShape::gpt_medium(gshard, cfg.batch, cfg.seq);
                let flops = device_flops(cluster);

                let ds = converged_counts(&DeepSpeedEven, &topo, &cfg);
                let fm = converged_counts(&FastMoeEven, &topo, &cfg);
                let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
                // DeepSpeed uses the hierarchical a2a; FastMoE/TA-MoE direct
                // (each policy's preferred_a2a).
                let thr_ds = throughput(&shape, &topo, &ds, 1, flops, A2aAlgo::Hierarchical);
                let thr_fm = throughput(&shape, &topo, &fm, 1, flops, A2aAlgo::Direct);
                let thr_ta = throughput(&shape, &topo, &ta, 1, flops, A2aAlgo::Direct);
                let s_ds = thr_ta / thr_ds;
                let s_fm = thr_ta / thr_fm;
                t.row(&[
                    p.to_string(),
                    format!("{thr_ds:.0}"),
                    format!("{thr_fm:.0}"),
                    format!("{thr_ta:.0}"),
                    format!("{s_ds:.2}x"),
                    format!("{s_fm:.2}x"),
                ]);
                results.push((cluster, gate, p, s_ds, s_fm));
            }
            t.print();
            println!();
        }
    }

    // Shape assertions: TA-MoE never loses, biggest wins on cluster C.
    let min_s = results.iter().map(|r| r.3.min(r.4)).fold(f64::INFINITY, f64::min);
    let max_c: f64 = results
        .iter()
        .filter(|r| r.0 == 'C')
        .map(|r| r.3.max(r.4))
        .fold(0.0, f64::max);
    let max_b: f64 = results
        .iter()
        .filter(|r| r.0 == 'B')
        .map(|r| r.3.max(r.4))
        .fold(0.0, f64::max);
    println!("paper ranges: 1.01x–1.61x vs DeepSpeed-MoE, 1.01x–4.77x vs FastMoE");
    println!(
        "ours: min speedup {min_s:.2}x; max on cluster C {max_c:.2}x; max on cluster B {max_b:.2}x"
    );
    assert!(min_s >= 0.99, "TA-MoE regressed somewhere: {min_s}");
    assert!(max_c > max_b, "cluster C should show the largest wins");

    let mut m = BTreeMap::new();
    m.insert("min_speedup".into(), Json::Num(min_s));
    m.insert("max_speedup_cluster_c".into(), Json::Num(max_c));
    record_jsonl("fig4_throughput", &Json::Obj(m));
}
